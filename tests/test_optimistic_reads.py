"""PR 10: optimistic lease-free reads (seqlock) + the async client pipeline.

Unit coverage for the read path's cost contract (home readers touch zero
simulated RDMA, remote readers pay exactly ONE doorbell and ZERO CAS per
attempt), its refusal discipline (live writer, armed intent barrier,
inflated word, takeover tombstone), publish fencing, the AsyncClient's
cross-call doorbell coalescing, and the batch-acquire doorbell budget
(the satellite fix for the 3.55-doorbells/op batch/shards16 row).

The hypothesis property test at the bottom drives random interleavings of
writer CAS traffic, publishes, expiries, mode changes and inflation flips
against the seqlock, asserting a returned snapshot is never torn (value
disagrees with its publish token) and never stale-epoch (token regresses
or exceeds what was ever published).
"""

import pytest

from repro.core import AsymmetricMemory, DeadlineExceeded
from repro.coord import AsyncClient, LeaseMode, ShardedLockTable
from repro.coord.table import (_TOMB_TOKEN, _dec, _enc, _infl)

TTL = 5.0


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


def _key_homed_on(table, host, salt="opt"):
    for i in range(50_000):
        k = f"{salt}/{i}"
        if table.home_of(k) == host:
            return k
    raise RuntimeError("no key found")


def _mk(num_nodes=3, num_shards=4):
    clock = FakeClock()
    mem = AsymmetricMemory(num_nodes)
    table = ShardedLockTable(mem, num_shards=num_shards, clock=clock)
    return clock, mem, table


# OpCounts.as_tuple() order:
# (local_read, local_write, local_cas, remote_read, remote_write,
#  remote_cas, remote_doorbell, timeouts, retries)
def _delta(p, snap):
    return tuple(a - b for a, b in zip(p.counts.as_tuple(), snap))


class TestReadCostContract:
    def test_cold_key_reads_nothing_published(self):
        clock, mem, table = _mk()
        p = mem.spawn(0)
        key = _key_homed_on(table, 0)
        assert table.read_optimistic(p, key) == (None, 0)

    def test_home_reader_pays_zero_rdma(self):
        clock, mem, table = _mk()
        home = mem.spawn(0)
        key = _key_homed_on(table, 0)
        lease = table.try_acquire(home, key, TTL)
        assert table.publish(home, lease, "v1")
        assert table.release(home, lease)
        snap = home.counts.as_tuple()
        assert table.read_optimistic(home, key) == ("v1", lease.token)
        d = _delta(home, snap)
        # 4 local reads (word, payload, word, intent); zero fabric.
        assert d[0] == 4
        assert d[3:7] == (0, 0, 0, 0), f"home reader touched fabric: {d}"

    def test_remote_reader_pays_one_doorbell_zero_cas(self):
        clock, mem, table = _mk()
        home = mem.spawn(0)
        remote = mem.spawn(1)
        key = _key_homed_on(table, 0)
        lease = table.try_acquire(home, key, TTL)
        assert table.publish(home, lease, "v1")
        assert table.release(home, lease)
        snap = remote.counts.as_tuple()
        assert table.read_optimistic(remote, key) == ("v1", lease.token)
        d = _delta(remote, snap)
        assert d[6] == 1, f"remote read cost {d[6]} doorbells, wanted 1"
        assert d[5] == 0, "remote read paid a CAS"
        assert d[3] == 4  # the 4-entry WR read set
        shard = table.shards[table.shard_of(key)]
        assert shard.opt_reads >= 1

    def test_publish_requires_live_exclusive_holder(self):
        clock, mem, table = _mk()
        p = mem.spawn(0)
        key = _key_homed_on(table, 0)
        sh = table.try_acquire(p, key, TTL, mode=LeaseMode.SHARED)
        assert sh is not None
        with pytest.raises(ValueError):
            table.publish(p, sh, "nope")  # shared may not publish
        assert table.release(p, sh)
        lease = table.try_acquire(p, key, TTL)
        assert table.publish(p, lease, "v1")
        assert table.release(p, lease)
        # A zombie (released) holder is fenced out once a newer
        # generation publishes.
        lease2 = table.try_acquire(p, key, TTL)
        assert table.publish(p, lease2, "v2")
        assert not table.publish(p, lease, "stale")
        assert table.release(p, lease2)
        assert table.read_optimistic(p, key) == ("v2", lease2.token)

    def test_deadline_refuses_before_any_fabric_op(self):
        clock, mem, table = _mk()
        remote = mem.spawn(1)
        key = _key_homed_on(table, 0)
        snap = remote.counts.as_tuple()
        clock.t = 10.0
        with pytest.raises(DeadlineExceeded):
            table.read_optimistic(remote, key, deadline=5.0)
        assert _delta(remote, snap) == (0,) * 9


class TestReadRefusals:
    def test_live_writer_refuses_without_blocking(self):
        clock, mem, table = _mk()
        writer = mem.spawn(0)
        reader = mem.spawn(1)
        key = _key_homed_on(table, 0)
        lease = table.try_acquire(writer, key, TTL)
        assert table.publish(writer, lease, "mid-write")
        # The holder is live: the read returns the retry signal rather
        # than a possibly-mid-publish payload, and never waits it out.
        assert table.read_optimistic(reader, key) is None
        assert table.release(writer, lease)
        assert table.read_optimistic(reader, key) == \
            ("mid-write", lease.token)

    def test_armed_intent_barrier_refuses(self):
        clock, mem, table = _mk()
        p = mem.spawn(0)
        reader = mem.spawn(1)
        key = _key_homed_on(table, 0)
        lease = table.try_acquire(p, key, TTL)
        assert table.publish(p, lease, "v")
        assert table.release(p, lease)
        st = table._key_state(table.shards[table.shard_of(key)], key)
        mem.write(p, st.intent, clock.t + 1.0)  # writer imminent
        assert table.read_optimistic(reader, key) is None
        mem.write(p, st.intent, 0.0)
        assert table.read_optimistic(reader, key) == ("v", lease.token)

    def test_inflated_word_routes_off_the_seqlock(self):
        clock, mem, table = _mk()
        p = mem.spawn(0)
        reader = mem.spawn(1)
        key = _key_homed_on(table, 0)
        lease = table.try_acquire(p, key, TTL)
        assert table.publish(p, lease, "v")
        assert table.release(p, lease)
        shard = table.shards[table.shard_of(key)]
        st = table._key_state(shard, key)
        word = mem.read(p, st.expires)
        assert mem.cas(p, st.expires, word,
                       (word[0], _enc(_dec(word[1]), True), word[2])) == word
        before = shard.opt_reads
        got = table.read_optimistic(reader, key)
        # Inflated mode bit set: the seqlock steps aside (no opt_read is
        # recorded); the result is the fallback's — correct or refused,
        # never a payload served around the queue discipline.
        assert shard.opt_reads == before
        assert shard.opt_read_fallbacks >= 1
        assert got is None or got == ("v", lease.token)

    def test_tombstone_verdict_forwards_never_serves(self):
        clock, mem, table = _mk()
        table_now = clock.t
        # Unit-level: a tombstoned word classifies as "forward" even with
        # a stable snapshot and a plausible payload attached.
        verdict, out = table._opt_read_verdict(
            table_now, (_TOMB_TOKEN, 0, 0.0), (7, "stale"),
            (_TOMB_TOKEN, 0, 0.0), 0.0)
        assert verdict == "forward"

    def test_post_takeover_read_never_returns_dead_home_payload(self):
        from repro.sim import SimEngine
        from repro.sim.fabric import (FabricFaults, FabricLatency,
                                      SimFabricMemory)
        engine = SimEngine(0)
        faults = FabricFaults(seed=0)
        mem = SimFabricMemory(4, engine, FabricLatency(), faults=faults)
        table = ShardedLockTable(mem, num_shards=8, clock=engine.clock,
                                 sleep=engine.sleep_inline, name="sim0")
        dead = 1
        key = _key_homed_on(table, dead, "tomb")
        writer = mem.spawn(3)
        lease = table.try_acquire(writer, key, 10.0)
        assert table.publish(writer, lease, ("secret", lease.token))
        assert table.release(writer, lease)
        reader = mem.spawn(2)
        assert table.read_optimistic(reader, key) == \
            (("secret", lease.token), lease.token)
        faults.fail_host(dead, engine.clock.now)

        class _Stub:
            def can_serve(self):
                return True

            def confirm_dead(self, host):
                return True

        p2 = mem.spawn(2)
        for s in list(table.shards):
            if s.home_host == dead:
                assert table.takeover_shard(p2, s.index, [],
                                            membership=_Stub()) is not None
        # The dead home's registers are tombstoned and the key re-homed
        # with a reset word: the old payload must never surface.
        got = table.read_optimistic(reader, key)
        assert got is None or got == (None, 0), \
            f"stale payload served across takeover: {got!r}"


class TestAsyncClientPipeline:
    def test_batched_reads_share_one_doorbell(self):
        clock, mem, table = _mk(num_nodes=3, num_shards=6)
        home = mem.spawn(0)
        keys = [_key_homed_on(table, 0, f"pipe{i}") for i in range(3)]
        toks = {}
        for k in keys:
            lease = table.try_acquire(home, k, TTL)
            assert table.publish(home, lease, f"val:{k}")
            assert table.release(home, lease)
            toks[k] = lease.token
        remote = mem.spawn(1)
        pl = AsyncClient(table, remote, flush_ops=8)
        snap = remote.counts.as_tuple()
        futs = [pl.read_optimistic(k) for k in keys]
        assert all(not f.done() for f in futs)
        assert _delta(remote, snap)[6] == 0  # nothing posted yet
        pl.flush()
        d = _delta(remote, snap)
        assert d[6] == 1, f"3 pipelined reads cost {d[6]} doorbells"
        assert d[5] == 0
        for k, f in zip(keys, futs):
            assert f.result() == (f"val:{k}", toks[k])
        assert pl.stats["flushes"] == 1
        assert pl.stats["reads_batched"] == 3

    def test_size_trigger_flushes_at_enqueue(self):
        clock, mem, table = _mk(num_nodes=3, num_shards=6)
        remote = mem.spawn(1)
        pl = AsyncClient(table, remote, flush_ops=2)
        keys = [_key_homed_on(table, 0, f"sz{i}") for i in range(2)]
        futs = [pl.read_optimistic(k) for k in keys]
        assert all(f.done() for f in futs)  # hit the size trigger
        assert pl.pending() == 0

    def test_quantum_trigger_flushes_on_poll(self):
        clock, mem, table = _mk(num_nodes=3, num_shards=6)
        remote = mem.spawn(1)
        pl = AsyncClient(table, remote, flush_ops=8, quantum=100e-6)
        fut = pl.read_optimistic(_key_homed_on(table, 0, "qk"))
        pl.poll()
        assert not fut.done()  # quantum not reached
        clock.t += 200e-6
        pl.poll()
        assert fut.done()

    def test_home_ops_resolve_inline(self):
        clock, mem, table = _mk()
        home = mem.spawn(0)
        pl = AsyncClient(table, home)
        key = _key_homed_on(table, 0)
        fut = pl.read_optimistic(key)
        assert fut.done() and fut.result() == (None, 0)
        assert pl.pending() == 0

    def test_renew_and_release_ride_the_flush(self):
        clock, mem, table = _mk(num_nodes=3, num_shards=6)
        remote = mem.spawn(1)
        pl = AsyncClient(table, remote, flush_ops=8)
        key = _key_homed_on(table, 0, "rr")
        lease = pl.sync(pl.acquire(key, TTL))
        assert lease is not None
        snap = remote.counts.as_tuple()
        rfut = pl.renew(lease)
        fut2 = pl.read_optimistic(_key_homed_on(table, 0, "rr2"))
        pl.flush()
        d = _delta(remote, snap)
        assert d[6] == 1, "renew + read did not share one posting"
        renewed = rfut.result()
        assert renewed is not None and renewed.token == lease.token
        assert pl.sync(pl.release(renewed)) is True
        shard = table.shards[table.shard_of(key)]
        assert shard.fast_renews >= 1 and shard.fast_releases >= 1
        assert fut2.done()

    def test_per_op_deadline_fails_at_flush_without_posting(self):
        clock, mem, table = _mk(num_nodes=3, num_shards=6)
        remote = mem.spawn(1)
        pl = AsyncClient(table, remote, flush_ops=8)
        fut = pl.read_optimistic(_key_homed_on(table, 0, "dl"),
                                 deadline=clock.t + 1e-6)
        clock.t += 1.0
        snap = remote.counts.as_tuple()
        pl.flush()
        assert _delta(remote, snap) == (0,) * 9  # doomed op never posted
        with pytest.raises(DeadlineExceeded):
            fut.result()

    def test_hedge_rides_a_queued_posting(self):
        clock, mem, table = _mk(num_nodes=3, num_shards=6)
        remote = mem.spawn(1)
        pl = AsyncClient(table, remote, flush_ops=8)
        key = _key_homed_on(table, 0, "hr")
        st = table._key_state(table.shards[table.shard_of(key)], key)
        fut = pl.read_optimistic(key)
        got = pl.ride_read(st.fence)  # the hedge shares the flush posting
        assert got == 0
        assert pl.stats["hedge_rides"] == 1
        assert fut.done()

    def test_pipeline_attaches_for_hedged_probes(self):
        clock, mem, table = _mk()
        remote = mem.spawn(1)
        pl = AsyncClient(table, remote)
        assert table._pipelines[remote.pid] is pl


class TestBatchDoorbellBudget:
    def test_cross_shard_batch_stays_under_two_doorbells_per_op(self):
        # The satellite fix: one host's shard groups chain their WR lists
        # (engagement piggybacks, merged re-read, one commit posting, all
        # grant writes on the first unlock), replacing the 3-doorbells-
        # per-group shape that benched at 3.55 doorbells/op.
        clock, mem, table = _mk(num_nodes=4, num_shards=16)
        p = mem.spawn(1)
        keys = []
        i = 0
        while len(keys) < 8:
            k = f"batch/k{i}"
            i += 1
            if table.home_of(k) == 0:
                keys.append(k)
        assert len({table.shard_of(k) for k in keys}) >= 3
        snap = p.counts.as_tuple()
        leases = table.acquire_batch(p, keys, TTL, timeout=5.0)
        assert len(leases) == len(keys)
        db_acq = _delta(p, snap)[6]
        snap = p.counts.as_tuple()
        assert table.release_batch(p, leases) == len(keys)
        db_rel = _delta(p, snap)[6]
        per_op = (db_acq + db_rel) / len(keys)
        assert per_op <= 2.0, \
            f"batch acquire+release cost {per_op:.2f} doorbells/op"
        assert db_rel <= 2, f"batch release cost {db_rel} doorbells"


# --------------------------------------------------------------------------
# Property test: torn/stale-read safety under random interleavings.
# Hypothesis drives the op sequences when available; otherwise an inline
# fuzzer generates them from fixed seeds (same op space, same invariants),
# so the property always runs.

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

KEY = "contested"

OPS = ("acquire_excl", "publish", "release", "read", "read_remote",
       "shared_join", "shared_leave", "upgrade", "downgrade",
       "inflate_flip", "advance", "zombie_publish")

def _check_torn_stale_property(ops, seed):
    clock = FakeClock()
    mem = AsymmetricMemory(3)
    table = ShardedLockTable(mem, num_shards=2, clock=clock)
    procs = [mem.spawn(h) for h in range(3)]
    shard = table.shards[table.shard_of(KEY)]

    held = {i: [] for i in range(3)}
    retired = []
    published = {}          # token -> value, every publish ever accepted
    max_published = 0
    last_read_token = 0
    inflated = False

    def check_read(got):
        nonlocal last_read_token
        if got is None:
            return  # refused: a writer/intent/inflation blocked it
        val, tok = got
        if tok == 0:
            assert val is None, f"token-0 read carried a value: {val!r}"
            assert max_published == 0 or last_read_token == 0
            return
        # Untorn: the value is exactly what was published under tok.
        assert tok in published and val == published[tok], (
            f"torn read: {val!r} under token {tok}")
        # Fresh: a token above every publish proves register corruption;
        # a token regressing below an earlier snapshot is a stale epoch.
        assert tok <= max_published
        assert tok >= last_read_token, (
            f"snapshot went back in time: {tok} < {last_read_token}")
        last_read_token = tok

    for kind, actor, mag in ops:
        p = procs[actor]
        if kind == "advance":
            clock.t += (mag + 1) * TTL / 6
        elif kind == "acquire_excl" and not inflated:
            lease = table.try_acquire(p, KEY, TTL)
            if lease is not None:
                held[actor].append(lease)
        elif kind == "publish" and held[actor]:
            lease = held[actor][mag % len(held[actor])]
            if lease.mode == LeaseMode.EXCLUSIVE:
                value = ("v", lease.token, mag)
                if table.publish(p, lease, value):
                    published[lease.token] = value
                    max_published = max(max_published, lease.token)
        elif kind == "zombie_publish" and retired:
            owner, lease = retired[mag % len(retired)]
            value = ("zombie", lease.token, mag)
            if (lease.mode == LeaseMode.EXCLUSIVE
                    and table.publish(procs[owner], lease, value)):
                # Accepted only while no newer generation published.
                assert lease.token >= max_published, \
                    "a fenced-out zombie publish landed"
                published[lease.token] = value
                max_published = max(max_published, lease.token)
        elif kind == "release" and held[actor]:
            lease = held[actor].pop(mag % len(held[actor]))
            table.release(p, lease)
            retired.append((actor, lease))
        elif kind in ("read", "read_remote"):
            # read: from the key's home host; read_remote: across the
            # fabric (one doorbell).  Same safety obligations.
            reader = (procs[shard.home_host] if kind == "read"
                      else procs[(shard.home_host + 1) % 3])
            check_read(table.read_optimistic(reader, KEY))
        elif kind == "shared_join" and not inflated:
            lease = table.try_acquire(p, KEY, TTL, mode=LeaseMode.SHARED)
            if lease is not None:
                held[actor].append(lease)
        elif kind == "shared_leave" and held[actor]:
            shared = [l for l in held[actor] if l.mode == LeaseMode.SHARED]
            if shared:
                lease = shared[mag % len(shared)]
                held[actor].remove(lease)
                table.release(p, lease)
                retired.append((actor, lease))
        elif kind == "upgrade" and held[actor]:
            shared = [l for l in held[actor] if l.mode == LeaseMode.SHARED]
            if shared:
                lease = shared[mag % len(shared)]
                up = table.upgrade(p, lease)
                if up is not None:
                    held[actor][held[actor].index(lease)] = up
        elif kind == "downgrade" and held[actor]:
            excl = [l for l in held[actor] if l.mode == LeaseMode.EXCLUSIVE]
            if excl:
                lease = excl[mag % len(excl)]
                down = table.downgrade(p, lease)
                if down is not None:
                    held[actor][held[actor].index(lease)] = down
        elif kind == "inflate_flip":
            # PR 7 mode bit flips under the reader's feet: the seqlock
            # must refuse or stay exact, never serve around the queue.
            st_key = table._key_state(shard, KEY)
            word = mem.auto_read(p, st_key.expires)
            flipped = (word[0], _enc(_dec(word[1]), not _infl(word[1])),
                       word[2])
            if mem.auto_cas(p, st_key.expires, word, flipped) == word:
                inflated = not _infl(word[1])
        # Expire local bookkeeping (the zombie pool).
        for i in range(3):
            for lease in list(held[i]):
                if clock.t >= lease.expires_at:
                    held[i].remove(lease)
                    retired.append((i, lease))

    # Whatever happened, a final read against a quiesced key (advance past
    # every horizon, deflate) is untorn and current.
    clock.t += 10 * TTL
    st_key = table._key_state(shard, KEY)
    word = mem.auto_read(procs[0], st_key.expires)
    if _infl(word[1]):
        mem.auto_cas(procs[0], st_key.expires, word,
                (word[0], _enc(_dec(word[1]), False), word[2]))
    got = table.read_optimistic(procs[1], KEY)
    check_read(got)


if HAVE_HYPOTHESIS:
    ops_strategy = st.lists(
        st.tuples(st.sampled_from(OPS), st.integers(0, 2),
                  st.integers(0, 7)),
        min_size=6, max_size=50,
    )

    @settings(max_examples=60, deadline=None)
    @given(ops=ops_strategy, seed=st.integers(0, 2 ** 16))
    def test_optimistic_reads_never_torn_or_stale(ops, seed):
        _check_torn_stale_property(ops, seed)
else:
    import random

    @pytest.mark.parametrize("seed", range(40))
    def test_optimistic_reads_never_torn_or_stale(seed):
        rng = random.Random(0xC0FFEE + seed)
        ops = [
            (rng.choice(OPS), rng.randrange(3), rng.randrange(8))
            for _ in range(rng.randint(6, 50))
        ]
        _check_torn_stale_property(ops, seed)
