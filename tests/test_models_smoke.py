"""Per-architecture smoke tests: one forward/train step on CPU, shape and
finiteness checks, decode-vs-forward consistency (deliverable f)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ShapeConfig, get_config
from repro.models import Model, input_specs

SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = input_specs(cfg, SHAPE, concrete=True, dtype=jnp.float32)
    loss, metrics = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.all(jnp.isfinite(g))), f"{arch}: non-finite grad at {path}"


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_hidden_shape(arch):
    cfg = get_config(arch, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = input_specs(cfg, SHAPE, concrete=True, dtype=jnp.float32)
    h, aux = model.forward(params, batch)
    T = SHAPE.seq_len
    assert h.shape == (SHAPE.global_batch, T, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))


@pytest.mark.parametrize(
    "arch", [a for a in ARCHS if get_config(a).causal]
)
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True).with_overrides(dtype="float32")
    if cfg.moe is not None:
        # generous capacity: capacity drops are legal divergence, not a bug
        cfg = cfg.with_overrides(
            moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    T, B, max_len = 24, 2, 32
    batch = input_specs(cfg, ShapeConfig("p", T, B, "prefill"), concrete=True,
                        dtype=jnp.float32)
    logits_p, cache = model.prefill(params, batch, max_len)

    h0, _ = model.forward(params, batch)
    ref_p = model._logits(params, h0)[:, -1:]
    np.testing.assert_allclose(np.asarray(logits_p), np.asarray(ref_p),
                               atol=2e-4, rtol=1e-3)

    tok = jax.random.randint(jax.random.PRNGKey(9), (B, 1), 0, cfg.vocab_size)
    logits_d, cache = model.decode_step(params, cache, tok)
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], tok], axis=1)
    h, _ = model.forward(params, batch2)
    ref_d = model._logits(params, h)[:, -1:]
    np.testing.assert_allclose(np.asarray(logits_d), np.asarray(ref_d),
                               atol=5e-3, rtol=1e-2)


def test_encoder_has_no_decode_shapes():
    from repro.configs import shape_cells

    cells = dict((s.name, skip) for s, skip in shape_cells("hubert-xlarge"))
    assert cells["decode_32k"] is not None
    assert cells["long_500k"] is not None
    assert cells["train_4k"] is None


def test_long_context_only_for_subquadratic():
    from repro.configs import shape_cells

    for arch in ARCHS:
        cells = dict((s.name, skip) for s, skip in shape_cells(arch))
        family = get_config(arch).family
        if family in ("hybrid", "ssm"):
            assert cells["long_500k"] is None, arch
        else:
            assert cells["long_500k"] is not None, arch


def test_param_counts_scale_with_config():
    """Full configs must be far larger than smoke ones (sanity on specs)."""
    from repro.models import param_count

    for arch in ARCHS:
        full = param_count(Model(get_config(arch)).specs())
        smoke = param_count(Model(get_config(arch, smoke=True)).specs())
        assert full > 50 * smoke, arch


@pytest.mark.parametrize(
    "arch,expected_b",
    [("llama3-8b", 8.0e9), ("llama3.2-1b", 1.2e9), ("deepseek-v2-236b", 236e9),
     ("deepseek-v3-671b", 671e9)],
)
def test_param_counts_match_published(arch, expected_b):
    from repro.models import param_count

    n = param_count(Model(get_config(arch)).specs())
    assert 0.75 * expected_b < n < 1.30 * expected_b, (
        f"{arch}: {n / 1e9:.1f}B vs published {expected_b / 1e9:.0f}B"
    )
