"""HLO analysis: trip counts, dot flops, collective classification."""

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hloparse import analyze


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_loopfree_dot_flops_match_cost_analysis():
    def g(x, w):
        for _ in range(4):
            x = jnp.tanh(x @ w)
        return x

    x = jax.ShapeDtypeStruct((64, 128), jnp.bfloat16)
    w = jax.ShapeDtypeStruct((128, 128), jnp.bfloat16)
    co = _compile(g, x, w)
    st = analyze(co.as_text(), num_devices=1, pod_size=256)
    expect = 4 * 2 * 64 * 128 * 128
    assert st.flops == expect
    # XLA's number includes elementwise flops; dots must dominate
    ca = co.cost_analysis()
    if isinstance(ca, list):  # jax 0.4.x wraps the dict in a 1-list
        ca = ca[0]
    assert st.flops <= ca["flops"] <= st.flops * 1.1


def test_scan_trip_count_multiplies_flops():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    x = jax.ShapeDtypeStruct((64, 128), jnp.bfloat16)
    w = jax.ShapeDtypeStruct((128, 128), jnp.bfloat16)
    st = analyze(_compile(f, x, w).as_text(), num_devices=1, pod_size=256)
    assert st.flops == 7 * 2 * 64 * 128 * 128


def test_nested_scan_trip_counts():
    def f(x, w):
        def inner(c, _):
            return jnp.tanh(c @ w), None

        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, None, length=3)
            return c, None

        y, _ = jax.lax.scan(outer, x, None, length=5)
        return y

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    st = analyze(_compile(f, x, w).as_text(), num_devices=1, pod_size=256)
    assert st.flops == 15 * 2 * 32 * 32 * 32


def test_dus_inplace_not_overcounted():
    """Scan stacking (dynamic-update-slice into a big buffer) must count the
    update bytes, not the whole buffer, per iteration."""
    def f(x):
        def body(c, _):
            return c + 1.0, c  # stacks [100, 1024] outputs

        _, ys = jax.lax.scan(body, x, None, length=100)
        return ys

    x = jax.ShapeDtypeStruct((1024,), jnp.float32)
    st = analyze(_compile(f, x).as_text(), num_devices=1, pod_size=256)
    full_buffer = 100 * 1024 * 4
    # naive counting would charge ~100 × full_buffer ≈ 41 MB; in-place model
    # must stay within a few × the buffer size.
    assert st.hbm_bytes < 6 * full_buffer, st.hbm_bytes
