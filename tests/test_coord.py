"""Coordination service: election + barrier on top of ALock."""

import threading

from repro.coord import Barrier, CoordinationService


def test_election_exactly_one_winner_per_epoch():
    svc = CoordinationService(num_hosts=4)
    for epoch in (10, 20, 30):
        wins = []

        def contend(host):
            p = svc.host_process(host)
            if svc.elect("writer", p, epoch=epoch):
                wins.append(host)

        ts = [threading.Thread(target=contend, args=(h,)) for h in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert len(wins) == 1, f"epoch {epoch}: winners={wins}"


def test_election_idempotent_within_epoch():
    svc = CoordinationService(num_hosts=2)
    p0 = svc.host_process(0)
    p1 = svc.host_process(1)
    assert svc.elect("w", p0, epoch=5)
    assert not svc.elect("w", p1, epoch=5)
    assert not svc.elect("w", p0, epoch=5)
    assert svc.elect("w", p1, epoch=6)


def test_barrier_all_arrive():
    svc = CoordinationService(num_hosts=3)
    bar = Barrier(svc, "epoch", parties=3)
    gens = []

    def arrive(host):
        p = svc.host_process(host)
        for _ in range(5):
            gens.append(bar.wait(p))

    ts = [threading.Thread(target=arrive, args=(h,)) for h in range(3)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    # 3 hosts × 5 rounds; every generation 0..4 seen exactly 3 times
    assert sorted(gens) == sorted(list(range(5)) * 3)
