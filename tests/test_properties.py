"""Hypothesis property tests on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed; skipping property tests")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.asymmetry import (
    allreduce_wire_bytes,
    cohort_vs_flat_dcn_bytes,
    reduce_scatter_wire_bytes,
)
from repro.models.attention import full_attention_reference, online_attention
from repro.optim import adamw_init, adamw_update


@settings(max_examples=25, deadline=None)
@given(
    T=st.integers(8, 64),
    H=st.sampled_from([2, 4]),
    K=st.sampled_from([1, 2]),
    d=st.sampled_from([8, 16]),
    qb=st.sampled_from([8, 16, 64]),
    kb=st.sampled_from([8, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_online_attention_equals_reference(T, H, K, d, qb, kb, causal, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, T, H, d), jnp.float32)
    k = jax.random.normal(ks[1], (1, T, K, d), jnp.float32)
    v = jax.random.normal(ks[2], (1, T, K, d), jnp.float32)
    a = online_attention(q, k, v, causal=causal, q_block=qb, k_block=kb)
    b = full_attention_reference(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)


@settings(max_examples=20, deadline=None)
@given(
    S=st.integers(4, 64),
    E=st.sampled_from([4, 8]),
    k=st.sampled_from([1, 2]),
    seed=st.integers(0, 2**16),
)
def test_moe_dispatch_conservation(S, E, k, seed):
    """Every token contributes ≤ k expert slots; outputs are finite; the
    scatter path equals the one-hot oracle whenever capacity suffices."""
    import dataclasses

    from repro.configs.base import ModelConfig, MoEConfig
    from repro.models.moe import moe_ffn, moe_spec
    from repro.models.specs import init_params

    cfg = ModelConfig(
        name="t", family="moe", num_layers=1, d_model=16, num_heads=2,
        num_kv_heads=2, d_ff=16, vocab_size=32,
        moe=MoEConfig(num_experts=E, top_k=k, d_expert=8,
                      capacity_factor=float(2 * k * E), router="softmax"),
    )
    params = init_params(moe_spec(cfg, jnp.float32), jax.random.PRNGKey(seed))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, S, 16), jnp.float32)
    y1, a1 = moe_ffn(params, x, cfg, dispatch="scatter")
    y2, a2 = moe_ffn(params, x, cfg, dispatch="onehot")
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    assert bool(jnp.isfinite(a1)) and bool(jnp.all(jnp.isfinite(y1)))


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    lr=st.floats(1e-5, 1e-2),
    steps=st.integers(1, 5),
)
def test_adamw_moves_toward_quadratic_minimum(seed, lr, steps):
    key = jax.random.PRNGKey(seed)
    target = jax.random.normal(key, (8,))
    params = {"w": jnp.zeros((8,))}
    state = adamw_init(params)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    l0 = loss(params)
    for _ in range(steps):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, lr,
                                        weight_decay=0.0, grad_clip=0.0)
    assert loss(params) <= l0 + 1e-6


@settings(max_examples=50, deadline=None)
@given(
    bytes_=st.floats(1.0, 1e12),
    pods=st.integers(2, 8),
    chips=st.sampled_from([4, 16, 64, 256]),
)
def test_cohort_always_reduces_dcn_bytes(bytes_, pods, chips):
    """The paper-mapped invariant: the cohort schedule's DCN traffic is the
    flat schedule's divided by the cohort size (the 'local class never
    touches the fabric' effect)."""
    r = cohort_vs_flat_dcn_bytes(bytes_, pods, chips)
    assert r["cohort_dcn_bytes_per_chip"] < r["flat_dcn_bytes_per_chip"]
    n = pods * chips
    expected = (2 * (n - 1) / n * bytes_) / (
        2 * (pods - 1) / pods * bytes_ / chips
    )
    np.testing.assert_allclose(r["reduction"], expected, rtol=1e-6)
    # the reduction is essentially the cohort size
    assert r["reduction"] > 0.9 * chips


@settings(max_examples=30, deadline=None)
@given(st.floats(1e3, 1e9), st.integers(2, 512))
def test_wire_byte_factors(payload, n):
    ar = allreduce_wire_bytes(payload, n)
    rs = reduce_scatter_wire_bytes(payload, n)
    assert np.isclose(ar, 2 * rs)
    assert 0 < ar < 2 * payload


@settings(max_examples=15, deadline=None)
@given(
    T=st.integers(4, 40),
    W=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**16),
)
def test_rglru_associative_scan_equals_sequential(T, W, seed):
    from repro.kernels.ref import rglru_scan_ref
    from repro.models.recurrent import rglru_scan

    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (2, T, W))) * 0.6 + 0.2
    b = jax.random.normal(ks[1], (2, T, W)) * 0.2
    h0 = jax.random.normal(ks[2], (2, W)) * 0.1
    got = rglru_scan(a, b, h0)
    exp = rglru_scan_ref(a, b, h0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp), atol=1e-5)
