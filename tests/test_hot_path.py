"""Hot-path fast paths: doorbell-batched postings, holder-validated
renewal/release CAS, shard-grouped batched acquisition, and the fencing
invariants that must survive them (see docs/lock-table.md, "Hot path")."""

import random
import threading

import pytest

from repro.core import AsymmetricMemory, OperationNotEnabled, make_scheduler
from repro.coord import CoordinationService, ShardedLockTable
from repro.coord.table import LOCAL, REMOTE


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def make_table(num_hosts=4, num_shards=8, clock=None, sched=None):
    mem = AsymmetricMemory(num_hosts, sched=sched)
    return mem, ShardedLockTable(mem, num_shards=num_shards, clock=clock)


def key_homed_on(table, host, salt=""):
    for i in range(10_000):
        k = f"hot{salt}-{i}"
        if table.home_of(k) == host:
            return k
    raise AssertionError(f"no key homed on host {host}")


def fast_renews(table):
    return sum(r["fast_renews"] for r in table.telemetry())


def fast_releases(table):
    return sum(r["fast_releases"] for r in table.telemetry())


# ----------------------------------------------------------- post_batch model
def test_post_batch_counts_one_doorbell_and_n_completions():
    mem = AsymmetricMemory(2)
    a = mem.alloc(0, "a", 1)
    b = mem.alloc(0, "b", 2)
    p = mem.spawn(1)
    out = mem.post_batch(p, [
        ("read", a), ("write", b, 7), ("cas", a, 1, 9), ("read", b),
    ])
    assert out == [1, None, 1, 7]
    assert p.counts.remote_doorbell == 1
    assert (p.counts.remote_read, p.counts.remote_write,
            p.counts.remote_cas) == (2, 1, 1)
    assert p.counts.rdma_ops == 4  # completions, the paper's cost unit
    # the CAS took effect (expected matched)
    assert mem.rread(p, a) == 9


def test_post_batch_executes_in_order():
    mem = AsymmetricMemory(2)
    a = mem.alloc(0, "a", 0)
    p = mem.spawn(1)
    out = mem.post_batch(p, [
        ("write", a, 5), ("read", a), ("cas", a, 5, 6), ("read", a),
    ])
    assert out == [None, 5, 5, 6]


def test_post_batch_rejects_cross_node_lists_and_local_posters():
    mem = AsymmetricMemory(3)
    a = mem.alloc(0, "a", 0)
    c = mem.alloc(1, "c", 0)
    remote = mem.spawn(2)
    with pytest.raises(ValueError, match="one queue pair"):
        mem.post_batch(remote, [("read", a), ("read", c)])
    local = mem.spawn(0)
    with pytest.raises(OperationNotEnabled):
        mem.post_batch(local, [("read", a)])
    assert mem.post_batch(remote, []) == []


def test_individual_remote_ops_ring_one_doorbell_each():
    mem = AsymmetricMemory(2)
    a = mem.alloc(0, "a", 0)
    p = mem.spawn(1)
    mem.rread(p, a)
    mem.rwrite(p, a, 1)
    mem.rcas(p, a, 1, 2)
    assert p.counts.remote_doorbell == 3  # no coalescing when posted alone


# ------------------------------------------------------- renewal fast path
def test_local_holder_renewal_is_zero_rdma_and_skips_the_alock():
    clock = FakeClock()
    mem, table = make_table(clock=clock)
    host = 1
    p = mem.spawn(host)
    k = key_homed_on(table, host)
    lease = table.try_acquire(p, k, ttl=5.0)
    snap = p.counts.snapshot()
    for _ in range(10):
        clock.advance(1.0)
        lease = table.renew(p, lease)
        assert lease is not None and lease.key == k
    d = p.counts.delta(snap)
    assert d.rdma_ops == 0, vars(d)
    assert d.local_cas == 10  # exactly one CAS per renewal, nothing else
    assert d.local_read == 0 and d.local_write == 0
    assert fast_renews(table) == 10


def test_remote_holder_renewal_is_exactly_one_rcas():
    clock = FakeClock()
    mem, table = make_table(clock=clock)
    k = key_homed_on(table, 0)
    p = mem.spawn(2)  # remote w.r.t. the key's home
    lease = table.acquire(p, k, ttl=5.0)
    snap = p.counts.snapshot()
    clock.advance(1.0)
    lease = table.renew(p, lease)
    assert lease is not None
    d = p.counts.delta(snap)
    assert d.remote_cas == 1 and d.rdma_ops == 1, vars(d)
    assert d.remote_doorbell == 1
    assert fast_renews(table) == 1


def test_zombie_fast_path_renewal_cas_loses_after_regrant():
    """The satellite claim: once a key is re-granted, the old holder's
    fast-path CAS must fail (the expiry register carries the new, larger
    fencing token — tokens are never reused, so no ABA)."""
    clock = FakeClock()
    mem, table = make_table(clock=clock)
    p0, p1 = mem.spawn(0), mem.spawn(1)
    zombie = table.try_acquire(p0, "k", ttl=5.0)
    assert zombie is not None
    clock.advance(5.0)  # the holder "pauses" past expiry
    regrant = table.try_acquire(p1, "k", ttl=100.0)
    assert regrant is not None and regrant.token > zombie.token
    # The zombie wakes believing its lease is live (its own expires_at is in
    # the past now, but force the fast path by handing it a future view).
    clock.t = 4.0  # rewind below the zombie's expiry: fast path is attempted
    assert table.renew(p0, zombie) is None
    assert fast_renews(table) == 0  # the CAS lost; no fast renewal recorded
    # The re-granted holder is untouched by the zombie's attempt.
    clock.t = 6.0
    renewed = table.renew(p1, regrant)
    assert renewed is not None and renewed.token == regrant.token


def test_expired_holder_renewal_takes_slow_path_and_fails():
    clock = FakeClock()
    mem, table = make_table(clock=clock)
    p = mem.spawn(0)
    lease = table.try_acquire(p, "k", ttl=5.0)
    clock.advance(5.0)
    assert table.renew(p, lease) is None  # now >= expires_at: no fast path
    assert fast_renews(table) == 0


# ------------------------------------------------------- release fast path
def test_local_holder_release_is_one_local_cas():
    mem, table = make_table()
    host = 3
    p = mem.spawn(host)
    k = key_homed_on(table, host)
    lease = table.try_acquire(p, k, ttl=5.0)
    snap = p.counts.snapshot()
    assert table.release(p, lease) is True
    d = p.counts.delta(snap)
    assert d.local_cas == 1 and d.local_ops == 1 and d.rdma_ops == 0, vars(d)
    assert fast_releases(table) == 1
    # Double release finds nothing to release.
    assert table.release(p, lease) is False
    # The key is free again and the next grant carries a larger token.
    nxt = table.try_acquire(p, k, ttl=5.0)
    assert nxt is not None and nxt.token > lease.token


def test_release_then_regrant_is_not_counted_as_expiration():
    mem, table = make_table()
    p = mem.spawn(0)
    lease = table.try_acquire(p, "k", ttl=60.0)
    assert table.release(p, lease)
    assert table.try_acquire(p, "k", ttl=60.0) is not None
    assert sum(r["expirations"] for r in table.telemetry()) == 0


def test_remote_holder_release_is_exactly_one_rcas():
    mem, table = make_table()
    k = key_homed_on(table, 0)
    p = mem.spawn(1)
    lease = table.acquire(p, k, ttl=5.0)
    snap = p.counts.snapshot()
    assert table.release(p, lease) is True
    d = p.counts.delta(snap)
    assert d.remote_cas == 1 and d.rdma_ops == 1, vars(d)


# --------------------------------------------------- shard-grouped batches
def test_shard_grouped_batch_grants_same_leases_as_per_key_path():
    """The grouped batch must be observably identical to the old per-key
    loop: same keys granted, same shard placement, same tokens, same
    expiries (one shared grant timestamp per shard group is the only
    difference, and FakeClock pins that)."""
    clock_a, clock_b = FakeClock(7.0), FakeClock(7.0)
    _, ta = make_table(num_shards=8, clock=clock_a)
    mem_b, tb = make_table(num_shards=8, clock=clock_b)
    keys = [f"txn/{i}" for i in range(12)]

    mem_a = ta.mem
    pa, pb = mem_a.spawn(1), mem_b.spawn(1)
    batch = ta.acquire_batch(pa, keys, ttl=9.0)
    per_key = [tb.acquire(pb, k, ttl=9.0) for k in tb.batch_order(keys)]

    def view(leases):
        return sorted(
            (l.key, l.shard, l.token, l.expires_at, l.ttl) for l in leases
        )

    assert view(batch) == view(per_key)
    assert ta.release_batch(pa, batch) == len(keys)


def test_batch_same_shard_keys_share_one_critical_section_doorbells():
    """O(distinct shards) critical sections: a remote batch over K keys of
    ONE shard costs the same ~3 postings as a single-key transaction
    (engage+reads, tail CAS, writes+drain) instead of K of each."""
    mem, table = make_table(num_hosts=2, num_shards=2)
    shard0 = [k for i in range(200)
              if table.shard_of(k := f"grp/{i}") == 0][:5]
    assert len(shard0) == 5
    home = table.shards[0].home_host
    p = mem.spawn(1 - home)  # remote to shard 0
    snap = p.counts.snapshot()
    leases = table.acquire_batch(p, shard0, ttl=30.0)
    d = p.counts.delta(snap)
    assert len(leases) == 5
    assert d.remote_doorbell <= 4, vars(d)  # NOT ~5x the single-key cost
    # ...while completions still account every register op.
    assert d.remote_read >= 5 and d.remote_write >= 10
    table.release_batch(p, leases)


def test_batch_stops_at_blocked_key_in_global_order():
    mem, table = make_table(num_shards=4)
    p0, p1 = mem.spawn(0), mem.spawn(1)
    keys = [f"b/{i}" for i in range(6)]
    ordered = table.batch_order(keys)
    blocker = table.try_acquire(p0, ordered[3], ttl=1e9)
    assert blocker is not None
    with pytest.raises(TimeoutError):
        table.acquire_batch(p1, keys, ttl=30.0, timeout=0.05)
    # rollback returned every earlier key: all grantable again
    for k in ordered[:3]:
        lease = table.try_acquire(p1, k, ttl=1.0)
        assert lease is not None
        table.release(p1, lease)


def test_piggybacked_expiry_reads_cannot_regrant_a_freshly_renewed_lease():
    """Regression: the granter's expiry verdict must use a clock sample no
    later than its (possibly piggybacked, pre-CS) register reads.  A holder
    that renews strictly before expiry — while the granter sits between its
    engagement posting and its verdict — must NOT lose its lease."""
    clock = FakeClock()
    hooks = {"armed": False, "fired": False}

    class RenewInWindow(AsymmetricMemory):
        def post_batch(self, p, wrs):
            out = super().post_batch(p, wrs)
            if hooks["armed"] and any(w[0] == "read" for w in wrs):
                hooks["armed"] = False
                hooks["fired"] = True
                # The healthy holder renews (pre-expiry, local CAS) while
                # the granter holds its stale reads; then time passes.
                renewed = hooks["renew"]()
                assert renewed is not None
                clock.advance(2.0)  # past the ORIGINAL expiry
            return out

    mem = RenewInWindow(2)
    table = ShardedLockTable(mem, num_shards=2, clock=clock)
    k = None
    for i in range(5000):
        if table.home_of(f"pg-{i}") == 0:
            k = f"pg-{i}"
            break
    holder = mem.spawn(0)  # local: renews via machine-local CAS
    granter = mem.spawn(1)  # remote: piggybacks reads on the engagement
    lease = table.try_acquire(holder, k, ttl=10.0)
    state = {"lease": lease}
    hooks["renew"] = lambda: state.__setitem__(
        "lease", table.renew(holder, state["lease"])
    ) or state["lease"]

    clock.t = 9.0  # granter arrives just before expiry
    hooks["armed"] = True
    stolen = table.try_acquire(granter, k, ttl=10.0)
    assert hooks["fired"], "engagement posting never carried the reads"
    assert stolen is None, "a freshly-renewed live lease was re-granted"
    # ...and the holder's lease is still fully operational.
    assert table.renew(holder, state["lease"]) is not None


# ------------------------------------------------ fencing under concurrency
@pytest.mark.parametrize("seed", [0, 1])
def test_fencing_tokens_strictly_monotonic_under_renew_vs_expire_races(seed):
    """Grant tokens must stay strictly increasing per key while a holder's
    fast-path renewals race contenders grabbing the key at expiry."""
    rng = random.Random(seed)
    clock = FakeClock()
    mem = AsymmetricMemory(3, sched=make_scheduler(rng, 0.2))
    table = ShardedLockTable(mem, num_shards=4, clock=clock)
    key = "contested"
    grants = []
    grant_mu = threading.Lock()
    stop = threading.Event()

    def ticker():
        while not stop.is_set():
            clock.advance(0.37)

    def holder(host):
        p = mem.spawn(host)
        lease = None
        while not stop.is_set():
            if lease is None:
                lease = table.try_acquire(p, key, ttl=1.0)
                if lease is not None:
                    with grant_mu:
                        grants.append(lease.token)
            else:
                lease = table.renew(p, lease)  # None once expired/re-granted

    ts = ([threading.Thread(target=ticker)]
          + [threading.Thread(target=holder, args=(h,)) for h in (0, 1, 2)])
    for t in ts:
        t.start()
    import time as _time
    _time.sleep(0.5)
    stop.set()
    for t in ts:
        t.join()

    assert len(grants) >= 3, "race never re-granted the key"
    assert grants == sorted(grants), grants
    assert len(set(grants)) == len(grants), grants


# ----------------------------------------------------- service lease cache
def test_service_cache_keeps_stale_lease_objects_on_the_fast_path():
    clock = FakeClock()
    svc = CoordinationService(num_hosts=2, num_shards=4, clock=clock)
    p = svc.host_process(0)
    first = svc.acquire(p, "cached", ttl=5.0)
    clock.advance(1.0)
    assert svc.renew(p, first) is not None
    clock.advance(1.0)
    # Renewing with the ORIGINAL (stale) lease object: without the cache the
    # CAS witness would mismatch and fall to the slow path; the cache
    # substitutes the freshest witness, so it stays a fast-path CAS.
    assert svc.renew(p, first) is not None
    assert sum(r["fast_renews"] for r in svc.telemetry()) == 2
    # A *different* token is never upgraded: it must fail fencing.
    import dataclasses
    forged = dataclasses.replace(first, token=first.token + 10)
    assert svc.renew(p, forged) is None


def test_service_cache_release_uses_freshest_witness():
    clock = FakeClock()
    svc = CoordinationService(num_hosts=2, num_shards=4, clock=clock)
    p = svc.host_process(1)
    first = svc.acquire(p, "rel", ttl=5.0)
    clock.advance(1.0)
    assert svc.renew(p, first) is not None
    # Release with the stale object: cache supplies the fresh witness, so
    # the release still succeeds (and on the fast path for local holders).
    assert svc.release(p, first) is True
    assert svc.try_acquire(p, "rel", ttl=5.0) is not None


# --------------------------------------------------------- class telemetry
def test_uncontended_remote_acquire_release_doorbell_budget():
    """The coalesced hot path: a lone remote client's whole acquire+release
    transaction fits in ≤5 doorbells (tail CAS, engage+reads, writes+drain,
    release CAS) — the pre-optimisation path posted every op individually
    (~14 postings)."""
    mem, table = make_table(num_hosts=2, num_shards=2)
    k = key_homed_on(table, 0)
    p = mem.spawn(1)
    lease = table.try_acquire(p, k, ttl=5.0)
    assert lease is not None
    assert table.release(p, lease)
    assert p.counts.remote_doorbell <= 5, vars(p.counts)
    totals = table.class_totals()
    assert totals[REMOTE].remote_doorbell == p.counts.remote_doorbell
    assert totals[LOCAL].rdma_ops == 0
