"""Cohort collectives (the paper's technique on the TPU fabric)."""

import pytest

from repro.core.asymmetry import TPUv5e, cohort_vs_flat_dcn_bytes


def test_cost_model_headline_numbers():
    """The napkin math quoted in DESIGN.md/EXPERIMENTS.md."""
    r = cohort_vs_flat_dcn_bytes(16.1e9, pods=2, chips_per_pod=256)
    # ratio = [2(n-1)/n] / [2(p-1)/p / chips] ≈ 2 × cohort size at p=2
    assert 500 < r["reduction"] < 520
    hw = TPUv5e()
    flat_s = r["flat_dcn_bytes_per_chip"] / hw.dcn_bw_per_chip
    coh_s = r["cohort_dcn_bytes_per_chip"] / hw.dcn_bw_per_chip
    assert coh_s < flat_s / 200


@pytest.mark.slow
def test_cohort_all_reduce_equals_flat(multidevice):
    out = multidevice(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.compat import make_mesh, set_mesh
from repro.core import cohort_all_reduce, flat_all_reduce
mesh = make_mesh((2,2,2), ('pod','data','model'))
tree = {'w': jnp.arange(24, dtype=jnp.float32).reshape(4,6),
        'b': jnp.ones((3,))*0.5}
with set_mesh(mesh):
    a = cohort_all_reduce(tree, mesh)
    b = flat_all_reduce(tree, mesh)
for k in tree:
    np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(a[k]), np.asarray(tree[k])*4, rtol=1e-6)
print('OK cohort')
""",
        devices=8,
    )
    assert "OK cohort" in out


@pytest.mark.slow
def test_int8_error_feedback_converges(multidevice):
    """Error feedback: repeated compressed exchanges of the SAME gradient
    must converge to the true mean (the residual is carried, not lost)."""
    out = multidevice(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, set_mesh, shard_map
from repro.core.cohort import pod_sync_grads, SyncConfig
mesh = make_mesh((2,2,2), ('pod','data','model'))
cfg = SyncConfig(mode='sync', compress_int8=True)
def body(g, e):
    return pod_sync_grads(g, cfg, e)
# fully manual: collectives-only body; partial-manual trips old-XLA bugs
f = shard_map(body, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
              axis_names=frozenset(mesh.axis_names), check_vma=False)
g = {'w': jax.random.normal(jax.random.PRNGKey(0), (8, 16))}
e = {'w': jnp.zeros((8, 16))}
total_err = []
with set_mesh(mesh):
    acc = jnp.zeros((8, 16))
    for i in range(24):
        m, e = jax.jit(f)(g, e)
        acc = acc + m['w']
        total_err.append(float(jnp.max(jnp.abs(acc / (i + 1) - g['w']))))
# single exchange is within quantization error; the EF-dithered running
# mean converges well below it (residual carried, not lost)
assert total_err[0] < 0.05, total_err[0]
assert total_err[-1] < total_err[0] / 3, total_err[::6]
print('OK ef', total_err[0], total_err[-1])
""",
        devices=8,
    )
    assert "OK ef" in out
