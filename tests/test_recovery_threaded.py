"""Threaded stress (satellite): kill and restart REAL client threads —
mid-acquire_batch and mid-reader-cohort — under a fuzzing scheduler.
Restarted clients must *reclaim* their leases (same fencing tokens) rather
than re-queue, and S/X exclusion must hold throughout.
"""

import random
import threading
import time

import pytest

from repro.core import AsymmetricMemory, make_scheduler
from repro.coord import (ClientCrash, FaultInjector, LeaseMode, LedgerStore,
                         RecoverableClient, ShardedLockTable)

SHARED, EXCLUSIVE = LeaseMode.SHARED, LeaseMode.EXCLUSIVE
TTL = 120.0  # real-clock tests: far longer than any test's wall time


def _distinct_shard_keys(table, count, prefix="k"):
    """Keys on pairwise distinct shards, so a batch spans several shard
    groups and the batch.mid crash window actually opens."""
    keys, seen = [], set()
    i = 0
    while len(keys) < count:
        key = f"{prefix}/{i}"
        i += 1
        s = table.shard_of(key)
        if s not in seen:
            seen.add(s)
            keys.append(key)
    return keys


def test_thread_killed_mid_batch_restarts_and_reclaims():
    rng = random.Random(7)
    mem = AsymmetricMemory(2, sched=make_scheduler(rng, 0.1))
    fi = FaultInjector()
    table = ShardedLockTable(mem, num_shards=8, fault=fi)
    store = LedgerStore()
    keys = _distinct_shard_keys(table, 5)

    p1 = mem.spawn(0)
    fi.at("batch.mid", nth=1, pid=p1.pid)
    rc = RecoverableClient(table, p1, store.ledger("victim"))
    box = {}

    def victim():
        try:
            rc.acquire_batch(keys, TTL)
            box["crashed"] = False
        except ClientCrash:
            box["crashed"] = True

    t = threading.Thread(target=victim)
    t.start()
    t.join()
    assert box["crashed"], "batch.mid never fired — keys span one shard?"
    # The dead thread holds a PREFIX of the batch at the word level with
    # no grant records — only dangling intents.  A stranger must still be
    # excluded from the held prefix.
    view = rc.ledger.replay()
    assert view.live == {} and set(view.intents) == set(keys)

    p2 = mem.spawn(1)
    got_box = {}

    def replacement():
        got_box["leases"] = rc.restart(p2)

    t2 = threading.Thread(target=replacement)
    t2.start()
    t2.join()
    got = got_box["leases"]
    assert got, "restart reclaimed nothing from the abandoned prefix"
    assert len(got) < len(keys)  # a prefix, not the full batch
    rows = table.telemetry()
    assert sum(r["orphan_adopts"] for r in rows) == len(got)
    # Reclaimed, not re-queued: the words still carry the original grants,
    # so a stranger is fenced out until WE release.
    stranger = mem.spawn(0)
    for lease in got:
        assert table.try_acquire(stranger, lease.key, TTL) is None
        assert rc.release(lease)
        assert table.try_acquire(stranger, lease.key, TTL) is not None
    # Intents past the crash point were resolved, never granted: free.
    for key in set(keys) - {l.key for l in got}:
        assert key not in rc.ledger.replay().intents


def test_reader_dies_mid_cohort_and_readopts_slot():
    rng = random.Random(11)
    mem = AsymmetricMemory(2, sched=make_scheduler(rng, 0.1))
    table = ShardedLockTable(mem, num_shards=4)
    store = LedgerStore()
    key = "cohort"

    survivor = mem.spawn(1)
    s_lease = table.try_acquire(survivor, key, TTL, mode=SHARED)
    assert s_lease is not None

    rc = RecoverableClient(table, mem.spawn(0), store.ledger("reader"))
    box = {}

    def reader():
        box["lease"] = rc.try_acquire(key, TTL, mode=SHARED)
        # ... and dies mid-cohort: no release, thread just ends.

    t = threading.Thread(target=reader)
    t.start()
    t.join()
    dead = box["lease"]
    assert dead is not None

    p2 = mem.spawn(0)
    got_box = {}

    def replacement():
        got_box["leases"] = rc.restart(p2)

    t2 = threading.Thread(target=replacement)
    t2.start()
    t2.join()
    (lease,) = got_box["leases"]
    assert lease.mode == SHARED
    assert lease.token == dead.token  # same reader generation: reclaimed
    # The cohort (survivor + re-adopted slot) still excludes writers...
    w = mem.spawn(1)
    assert table.try_acquire(w, key, TTL) is None
    # ...and the re-adopted slot is a REAL slot: both releases drain it.
    assert rc.release(lease)
    assert table.try_acquire(w, key, TTL) is None  # survivor still in
    assert table.release(survivor, s_lease)
    assert table.try_acquire(w, key, TTL) is not None


@pytest.mark.parametrize("seed", [1, 2])
def test_crash_restart_stress_holds_sx_exclusion(seed):
    """Workers crash (abandon their lease), restart, and must RECLAIM the
    same grant — token preserved — while S/X exclusion holds across every
    interleaving the fuzzing scheduler can produce."""
    rng = random.Random(seed)
    mem = AsymmetricMemory(1, sched=make_scheduler(rng, 0.15))
    table = ShardedLockTable(mem, num_shards=2)
    store = LedgerStore()
    key = "stressed"
    state = {"readers": 0, "writers": 0, "violations": 0,
             "reclaims": 0, "token_mismatch": 0}
    mu = threading.Lock()

    def worker(widx):
        r = random.Random(1000 * seed + widx)
        rc = RecoverableClient(table, mem.spawn(0),
                               store.ledger(f"w{widx}"))
        for _ in range(12):
            exclusive = r.random() < 0.4
            mode = EXCLUSIVE if exclusive else SHARED
            lease = None
            deadline = time.monotonic() + 60.0
            while lease is None and time.monotonic() < deadline:
                lease = rc.try_acquire(key, TTL, mode=mode)
                if lease is None:
                    time.sleep(0.0005)
            assert lease is not None
            with mu:
                if exclusive:
                    state["writers"] += 1
                    if state["writers"] != 1 or state["readers"] != 0:
                        state["violations"] += 1
                else:
                    state["readers"] += 1
                    if state["writers"] != 0:
                        state["violations"] += 1
            time.sleep(0.001)
            if exclusive and r.random() < 0.5:
                # Crash: abandon the lease, restart, reclaim.  The word is
                # never released in between, so the exclusion bookkeeping
                # stays exactly as it was — any overlap is a violation.
                # Only writers crash here: a reader that dies while a
                # writer is DRAINING is refused re-adoption (the barrier
                # rule) and its slot waits out the horizon — correct, but
                # a 120s-TTL wedge this real-clock test cannot sit out.
                # Reader death mid-cohort is covered above.
                got = rc.restart(mem.spawn(0))
                with mu:
                    state["reclaims"] += len(got)
                    if (len(got) != 1 or got[0].key != key
                            or got[0].token != lease.token):
                        state["token_mismatch"] += 1
                lease = got[0] if got else lease
            with mu:
                if exclusive:
                    if state["writers"] != 1 or state["readers"] != 0:
                        state["violations"] += 1
                    state["writers"] -= 1
                else:
                    if state["writers"] != 0:
                        state["violations"] += 1
                    state["readers"] -= 1
            rc.release(lease)

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(5)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert state["violations"] == 0, state
    assert state["token_mismatch"] == 0, state
    assert state["reclaims"] > 0, "no worker ever exercised crash-restart"
    rows = table.telemetry()
    assert sum(r["reclaims"] for r in rows) == state["reclaims"]
