"""Property tests (satellite): ledger replay is an idempotent,
duplication-tolerant fold, and writer fencing tokens stay monotonic across
crash / restart-reclaim / expiry / zombie interleavings.

Runs under Hypothesis when it is installed; the container ships without it,
so the same properties also run as a seeded inline fuzz (deterministic
seeds, identical drivers) — the hypothesis path simply widens the search
when available instead of skipping the invariants entirely.
"""

import random

import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import AsymmetricMemory
from repro.coord import (LeaseMode, LedgerStore, RecoverableClient,
                         ShardedLockTable, replay_records)
from repro.coord.ledger import LeaseLedger


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _view_key(view):
    return (sorted(view.live.items()), sorted(view.intents.items()),
            view.pids)


# ----------------------------------------------------- replay fold property
def _random_records(rng: random.Random) -> LeaseLedger:
    """An arbitrary (not necessarily protocol-legal) record stream: replay
    must stay a well-defined pure fold even over garbage orderings."""
    led = LeaseLedger("fuzz")
    keys = ["a", "b", "c"]
    for _ in range(rng.randrange(1, 40)):
        op = rng.choice(("session", "intent", "grant", "reclaim", "renew",
                         "release", "lost", "resolve"))
        led.append(op, key=rng.choice(keys), shard=rng.randrange(4),
                   token=rng.randrange(1, 6),
                   mode=rng.choice((int(LeaseMode.SHARED),
                                    int(LeaseMode.EXCLUSIVE))),
                   expires_at=rng.uniform(0.0, 10.0),
                   ttl=rng.uniform(0.1, 2.0), pid=rng.randrange(4))
    return led


def _check_replay_fold(rng: random.Random) -> None:
    led = _random_records(rng)
    base = _view_key(led.replay())
    # Pure: replaying again gives the same view.
    assert _view_key(led.replay()) == base
    # Crash-retry tolerant: duplicating ANY record in place is a no-op —
    # a client that died before learning its append landed may re-append.
    recs = led.records
    for i in range(len(recs)):
        doubled = recs[: i + 1] + [recs[i]] + recs[i + 1:]
        assert _view_key(replay_records(doubled)) == base, (
            f"duplicating record {i} ({recs[i].op}) changed the view")
    # Prefix-extensible: replay of a prefix then the suffix records agrees
    # with one full fold (no hidden cross-record state).
    if len(recs) > 1:
        cut = rng.randrange(1, len(recs))
        assert _view_key(replay_records(recs[:cut] + recs[cut:])) == base


# -------------------------------------------- token monotonicity property
def _check_token_monotonic(rng: random.Random) -> None:
    """Drive a real table through a random interleaving of grants, renews,
    releases, expiries, crash-restarts (reclaiming and amnesiac) and zombie
    renewals; check the fencing invariants after every step."""
    clock = FakeClock()
    mem = AsymmetricMemory(4)
    table = ShardedLockTable(mem, num_shards=4, clock=clock)
    store = LedgerStore()
    keys = ["k0", "k1"]
    ttl = 10.0

    clients = []  # [rc, held: {key: lease}]
    for i in range(3):
        rc = RecoverableClient(table, mem.spawn(i % 4),
                               store.ledger(f"c{i}"))
        clients.append([rc, {}])

    max_tok = {k: 0 for k in keys}   # largest writer token ever granted
    graveyard = []                   # (rc_owner_index, stale lease copies)

    for _ in range(120):
        i = rng.randrange(len(clients))
        rc, held = clients[i]
        act = rng.random()
        if act < 0.30:  # acquire (mostly exclusive, some shared)
            key = rng.choice(keys)
            if key in held:
                continue
            mode = LeaseMode.SHARED if rng.random() < 0.25 \
                else LeaseMode.EXCLUSIVE
            lease = rc.try_acquire(key, ttl, mode=mode)
            if lease is None:
                continue
            if mode == LeaseMode.EXCLUSIVE:
                assert lease.token > max_tok[key], (
                    "exclusive grant reused a fencing token")
                max_tok[key] = lease.token
            else:
                assert lease.token >= max_tok[key], (
                    "reader generation fell behind the writer fence")
            held[key] = lease
        elif act < 0.45:  # renew: fencing identity is immutable
            if not held:
                continue
            key = rng.choice(sorted(held))
            renewed = rc.renew(held[key])
            if renewed is None:
                del held[key]
            else:
                assert renewed.token == held[key].token
                held[key] = renewed
        elif act < 0.60:  # release
            if not held:
                continue
            key = rng.choice(sorted(held))
            rc.release(held.pop(key))
        elif act < 0.72:  # time passes (sometimes past expiry)
            clock.advance(rng.choice((1.0, 4.0, ttl + 1.0)))
        elif act < 0.90:  # crash + restart
            for key, lease in held.items():
                graveyard.append(lease)  # the dead incarnation's handles
            held.clear()
            p2 = mem.spawn(rng.randrange(4))
            if rng.random() < 0.7:  # recovery path: replay + reclaim
                for lease in rc.restart(p2):
                    # Reclaim resumes the SAME grant: token equality, never
                    # a fresh allocation, never a regression.
                    assert lease.token <= max_tok[lease.key]
                    held[lease.key] = lease
            else:  # amnesiac path: rejoins as a stranger
                rc.adopt_process(p2)
        else:  # zombie renewal: a fenced-out handle must stay dead
            if not graveyard:
                continue
            stale = rng.choice(graveyard)
            if max_tok[stale.key] > stale.token:
                zombie_p = mem.spawn(0)
                assert table.renew(zombie_p, stale) is None, (
                    "zombie renewed past a newer fencing token")

    # Final sweep: every zombie whose key moved on is permanently fenced.
    zp = mem.spawn(0)
    for stale in graveyard:
        if max_tok[stale.key] > stale.token:
            assert table.renew(zp, stale) is None


# --------------------------------------------------------------- test glue
if HAVE_HYPOTHESIS:

    @settings(max_examples=60, deadline=None)
    @given(seed=st.integers(0, 2 ** 32 - 1))
    def test_replay_fold_properties(seed):
        _check_replay_fold(random.Random(seed))

    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 2 ** 32 - 1))
    def test_fencing_tokens_monotonic_across_crashes(seed):
        _check_token_monotonic(random.Random(seed))

else:

    @pytest.mark.parametrize("seed", range(60))
    def test_replay_fold_properties(seed):
        _check_replay_fold(random.Random(seed))

    @pytest.mark.parametrize("seed", range(40))
    def test_fencing_tokens_monotonic_across_crashes(seed):
        _check_token_monotonic(random.Random(seed))
