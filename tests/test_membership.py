"""Membership unit tests: the suspicion estimator's verdict machine, the
policy's fencing inequality, member-key homing, and successor rank order.

The estimator is driven directly (no fabric) so every transition boundary
is pinned by hand-placed observation times; the one integration test runs
real heartbeat/monitor tasks on the sim fabric and checks the detection
floor that anchors the partition-guard proof: a DEAD verdict can never
land earlier than ``ttl`` after the host's last renewal reached its word.
"""

import pytest

from repro.coord import (ALIVE, DEAD, SUSPECT, HostMembership,
                         SuspicionEstimator, SuspicionPolicy,
                         member_key_for)
from repro.coord.table import ShardedLockTable
from repro.core import AsymmetricMemory
from repro.sim import SimEngine
from repro.sim.fabric import FabricFaults, FabricLatency, SimFabricMemory

TTL = 1e-3


def _policy(**kw):
    kw.setdefault("ttl", TTL)
    return SuspicionPolicy(**kw)


# A miss sequence that legitimately kills a host under the default
# thresholds: two quick misses reach SUSPECT (windowed rate >= 2), two
# more extend the streak to dead_misses=4, and the last lands > ttl after
# the first so the duration term is satisfied too.
KILL_TIMES = (1e-4, 2e-4, 3e-4, 1.2e-3)


def _feed_kill(est, host, t0=0.0):
    for t in KILL_TIMES:
        est.miss(host, t0 + t, expired=False)


class TestSuspicionPolicy:
    def test_defaults_derive_from_ttl(self):
        p = _policy()
        assert p.beat_every == TTL / 4
        assert p.sweep_every == TTL / 4
        assert p.window == 2 * TTL
        assert p.guard_ttl == TTL

    def test_fencing_inequality_enforced(self):
        # guard_ttl must lapse before any observer can reach DEAD.
        with pytest.raises(ValueError, match="guard_ttl"):
            _policy(guard_ttl=1.5 * TTL)
        _policy(guard_ttl=TTL)          # boundary is legal
        _policy(guard_ttl=TTL / 2)      # undercutting is legal

    def test_rejects_nonsense(self):
        with pytest.raises(ValueError):
            _policy(ttl=0.0)
        with pytest.raises(ValueError):
            _policy(beat_every=2 * TTL)  # heartbeat slower than the lease
        with pytest.raises(ValueError):
            _policy(sweep_every=3 * TTL)
        with pytest.raises(ValueError):
            _policy(suspect_misses=5.0, dead_misses=2.0)
        with pytest.raises(ValueError):
            _policy(recover_beats=0)


class TestSuspicionEstimator:
    def test_alive_to_suspect_on_windowed_misses(self):
        est = SuspicionEstimator(_policy())
        assert est.verdict(3) == ALIVE
        est.miss(3, 1e-4, expired=True)
        assert est.verdict(3) == ALIVE
        est.miss(3, 2e-4, expired=True)
        assert est.verdict(3) == SUSPECT

    def test_dead_needs_streak_and_duration(self):
        est = SuspicionEstimator(_policy())
        # Four consecutive misses inside < ttl: streak satisfied, duration
        # not — the host has not been missing long enough to have lapsed.
        for t in (1e-4, 2e-4, 3e-4, 4e-4):
            est.miss(9, t, expired=False)
        assert est.verdict(9) == SUSPECT
        # The next miss past the ttl horizon finishes the escalation.
        est.miss(9, 1.2e-3, expired=False)
        assert est.verdict(9) == DEAD
        assert est.died_at(9) == pytest.approx(1.2e-3)

    def test_interleaved_beat_resets_the_streak(self):
        est = SuspicionEstimator(_policy())
        est.miss(4, 1e-4, expired=False)
        est.miss(4, 2e-4, expired=False)
        est.beat(4, 3e-4)                  # one live word interrupts
        for t in (4e-4, 5e-4, 6e-4):
            est.miss(4, t, expired=False)
        # Streak restarted at 4e-4: only 3 consecutive misses and only
        # 0.2 ms of continuous missing — nowhere near DEAD.
        assert est.verdict(4) == SUSPECT
        est.miss(4, 1.5e-3, expired=True)  # 4th consecutive, > ttl missing
        assert est.verdict(4) == DEAD

    def test_sparse_misses_decay_out_of_the_window(self):
        est = SuspicionEstimator(_policy())
        # One miss every two windows: the previous bucket is empty by the
        # time the next miss lands, so the rate never reaches 2.
        for i in range(6):
            est.miss(7, 1e-4 + i * 2 * est.policy.window, expired=True)
        assert est.verdict(7) == ALIVE

    def test_recovery_needs_consecutive_beats(self):
        est = SuspicionEstimator(_policy())
        _feed_kill(est, 2)
        assert est.verdict(2) == DEAD
        est.beat(2, 2.0e-3)
        est.beat(2, 2.1e-3)
        assert est.verdict(2) == DEAD       # recover_beats=3 not yet met
        est.beat(2, 2.2e-3)
        assert est.verdict(2) == ALIVE
        assert est.died_at(2) is None
        # The transition log recorded the full round trip.
        assert [(h, old, new) for _t, h, old, new in est.transitions] == [
            (2, ALIVE, SUSPECT), (2, SUSPECT, DEAD), (2, DEAD, ALIVE)]

    def test_miss_flavours_are_equivalent_for_the_clock(self):
        # A probe TIMEOUT (fabric ate the host) must start the same
        # DEAD-eligibility clock as an observably expired word.
        for expired in (True, False):
            est = SuspicionEstimator(_policy())
            for t in KILL_TIMES:
                est.miss(1, t, expired=expired)
            assert est.verdict(1) == DEAD


class TestMemberKeys:
    def test_member_keys_home_on_their_host(self):
        mem = AsymmetricMemory(8)
        table = ShardedLockTable(mem, num_shards=16)
        for h in range(8):
            key = member_key_for(table, h, 8)
            assert table.home_of(key) == h
            # Deterministic: every observer computes the same key.
            assert member_key_for(table, h, 8) == key


class TestSuccessor:
    def _membership(self, num_hosts=5):
        mem = AsymmetricMemory(num_hosts)
        table = ShardedLockTable(mem, num_shards=2 * num_hosts)
        return HostMembership(table, mem, 0, num_hosts, policy=_policy())

    def test_ring_order_skips_dead(self):
        m = self._membership()
        assert m.successor(2) == 3
        _feed_kill(m.estimator, 3)
        assert m.successor(2) == 4
        _feed_kill(m.estimator, 4)
        assert m.successor(2) == 0 and m.is_successor(2)

    def test_wraps_around_the_ring(self):
        m = self._membership()
        assert m.successor(4) == 0
        assert m.live_hosts() == [0, 1, 2, 3, 4]

    def test_no_successor_when_everyone_is_dead(self):
        m = self._membership(num_hosts=3)
        _feed_kill(m.estimator, 1)
        _feed_kill(m.estimator, 2)
        # Only self is left; self is never DEAD in its own view.
        assert m.successor(1) == 0
        _feed_kill(m.estimator, 0)
        assert m.successor(1) is None


class TestDetectionFloor:
    def test_dead_verdict_lands_after_ttl_of_silence(self):
        """Integration: real heartbeats on the sim fabric.  Kill a host
        and check the monitor's DEAD verdict arrives no earlier than one
        ttl after the death — the floor the guard_ttl <= ttl inequality
        fences against — and within a few sweep periods after it."""
        n = 4
        engine = SimEngine(0)
        faults = FabricFaults(seed=0)
        mem = SimFabricMemory(n, engine, FabricLatency(), faults=faults)
        table = ShardedLockTable(mem, num_shards=2 * n, clock=engine.clock,
                                 sleep=engine.sleep_inline, name="sim0")
        pol = SuspicionPolicy(ttl=2e-3)
        members = [HostMembership(table, mem, h, n, policy=pol)
                   for h in range(n)]
        for h, m in enumerate(members):
            engine.spawn(m.heartbeat_task(), delay=h * 1e-7)
            engine.spawn(m.monitor_task(), delay=pol.ttl / 2 + h * 1e-7)
        t_kill = 5e-3
        faults.fail_host(3, t_kill)
        watcher = members[0]
        engine.run(stop=lambda: watcher.estimator.verdict(3) == DEAD,
                   max_events=200_000)
        died = watcher.estimator.died_at(3)
        assert died is not None, "monitor never reached a DEAD verdict"
        assert died - t_kill >= pol.ttl, \
            "DEAD landed before the member lease could have lapsed"
        assert died - t_kill <= 6 * pol.ttl
        # Ring order: every live observer picks the same successor.
        assert watcher.successor(3) == 0
        for m in members:
            m.stop()
