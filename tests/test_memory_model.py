"""Paper §2 / Table 1: operation-asymmetry semantics of the simulated fabric."""

import random
import threading

import pytest

from repro.core import (
    AsymmetricMemory,
    BrokenMixedCASLock,
    NULLPTR,
    OperationNotEnabled,
    make_scheduler,
)


def test_locality_enforced():
    mem = AsymmetricMemory(2)
    reg = mem.alloc(0, "r")
    local = mem.spawn(0)
    remote = mem.spawn(1)
    mem.write(local, reg, 7)
    assert mem.read(local, reg) == 7
    with pytest.raises(OperationNotEnabled):
        mem.read(remote, reg)
    with pytest.raises(OperationNotEnabled):
        mem.write(remote, reg, 1)
    with pytest.raises(OperationNotEnabled):
        mem.cas(remote, reg, 7, 1)


def test_remote_ops_enabled_for_all_including_loopback():
    """Remote accesses are enabled for every process (RDMA loopback)."""
    mem = AsymmetricMemory(2)
    reg = mem.alloc(0, "r", 0)
    local = mem.spawn(0)
    assert mem.rcas(local, reg, 0, 5) == 0
    assert mem.rread(local, reg) == 5
    mem.rwrite(local, reg, 9)
    assert mem.read(local, reg) == 9
    assert local.counts.rdma_ops == 3


def test_op_accounting():
    mem = AsymmetricMemory(2)
    reg = mem.alloc(0, "r", 0)
    p = mem.spawn(1)
    snap = p.counts.snapshot()
    mem.rread(p, reg)
    mem.rwrite(p, reg, 1)
    mem.rcas(p, reg, 1, 2)
    d = p.counts.delta(snap)
    assert (d.remote_read, d.remote_write, d.remote_cas) == (1, 1, 1)
    assert d.local_ops == 0


def test_rcas_not_atomic_with_local_cas():
    """Table 1: remote RMW is NOT atomic w.r.t. local RMW — a mixed-CAS lock
    admits two holders (lost update). Deterministic interleaving: the rCAS
    is held inside its read→write window while a local CAS takes the lock;
    the rCAS's stale compare then succeeds anyway — exactly the hazard the
    paper's design eliminates."""
    window_open = threading.Event()
    local_done = threading.Event()

    def sched(*tags):
        if "rcas_window" in tags:
            window_open.set()
            assert local_done.wait(5), "local CAS never ran"

    mem = AsymmetricMemory(2, sched=sched)
    lock = BrokenMixedCASLock(mem, home_node=0)
    remote = mem.spawn(1)
    local = mem.spawn(0)
    state = []

    def remote_thread():
        lock.lock(remote)          # rCAS blocks inside its window
        state.append("remote_in_cs")

    t = threading.Thread(target=remote_thread)
    t.start()
    assert window_open.wait(5)
    # Local process takes the lock with an atomic machine CAS while the
    # RNIC compare is in flight.
    lock.lock(local)
    state.append("local_in_cs")
    local_done.set()
    t.join(timeout=5)
    assert state == ["local_in_cs", "remote_in_cs"], state
    # both "hold" the lock simultaneously: mutual exclusion violated.


def test_rcas_serialized_against_rcas():
    """Remote RMWs ARE mutually atomic (RNIC serialisation): an all-rCAS
    counter increment loses no updates."""
    mem = AsymmetricMemory(3, sched=make_scheduler(random.Random(1), 0.3))
    reg = mem.alloc(0, "ctr", 0)

    def worker(node, iters=100):
        p = mem.spawn(node)
        for _ in range(iters):
            while True:
                cur = mem.rread(p, reg)
                if mem.rcas(p, reg, cur, cur + 1) == cur:
                    break

    ts = [threading.Thread(target=worker, args=(n,)) for n in (0, 1, 2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert mem.read(mem.spawn(0), reg) == 300
