"""Data pipeline: determinism, host sharding, stateless resume."""

import numpy as np

from repro.configs import ShapeConfig, get_config
from repro.data import SyntheticLMDataset, make_batch_iterator

CFG = get_config("llama3.2-1b", smoke=True)
SHAPE = ShapeConfig("t", seq_len=16, global_batch=8, kind="train")


def test_batches_deterministic():
    d1 = SyntheticLMDataset(CFG, SHAPE, seed=3)
    d2 = SyntheticLMDataset(CFG, SHAPE, seed=3)
    b1, b2 = d1.batch(5), d2.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["labels"], b2["labels"])


def test_different_steps_differ():
    d = SyntheticLMDataset(CFG, SHAPE, seed=3)
    assert not np.array_equal(d.batch(0)["tokens"], d.batch(1)["tokens"])


def test_labels_are_next_tokens():
    d = SyntheticLMDataset(CFG, SHAPE, seed=0)
    b = d.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_markov_structure_learnable():
    """Successor entropy must be far below vocab entropy (the signal the e2e
    example trains on)."""
    d = SyntheticLMDataset(CFG, SHAPE, seed=0, branching=4)
    b = d.batch(0)
    # every (cur -> next) transition must be one of the 4 designated successors
    succ = d.successors
    cur, nxt = b["tokens"][:, :-1].ravel(), b["tokens"][:, 1:].ravel()
    ok = np.any(succ[cur] == nxt[:, None], axis=1)
    assert ok.all()


def test_host_shards_partition_global_batch():
    d = SyntheticLMDataset(CFG, SHAPE, seed=1)
    full_rows = SHAPE.global_batch
    parts = [d.batch(2, host=h, num_hosts=4) for h in range(4)]
    assert all(p["tokens"].shape[0] == full_rows // 4 for p in parts)
    # host shards must differ (they draw from per-host streams)
    assert not np.array_equal(parts[0]["tokens"], parts[1]["tokens"])


def test_iterator_resumes_at_step():
    d = SyntheticLMDataset(CFG, SHAPE, seed=1)
    it = make_batch_iterator(d, start_step=10)
    first = next(it)
    it.close()
    np.testing.assert_array_equal(first["tokens"], d.batch(10)["tokens"])


def test_vlm_and_audio_batches():
    vcfg = get_config("internvl2-76b", smoke=True)
    vb = SyntheticLMDataset(vcfg, SHAPE, seed=0).batch(0)
    assert vb["embeds"].shape == (8, vcfg.frontend_tokens, vcfg.d_model)
    assert vb["tokens"].shape[1] == SHAPE.seq_len - vcfg.frontend_tokens

    acfg = get_config("hubert-xlarge", smoke=True)
    ab = SyntheticLMDataset(acfg, SHAPE, seed=0).batch(0)
    assert ab["embeds"].shape == (8, SHAPE.seq_len, acfg.d_model)
    assert ab["labels"].shape == (8, SHAPE.seq_len)
