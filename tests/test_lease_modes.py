"""Mode-aware lease stack: shared cohorts on the packed S/X word, writer
drain via the intent barrier, upgrade/downgrade transitions, per-mode
telemetry and costs, and the shard-grouped batched release (see
docs/lock-table.md, "Lease modes")."""

import dataclasses
import threading
import time

import pytest

from repro.core import AsymmetricMemory
from repro.coord import CoordinationService, LeaseMode, ShardedLockTable
from repro.coord.table import EXCLUSIVE, LOCAL, REMOTE, SHARED
from repro.launch.serve import BatchAdmission


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def make_table(num_hosts=4, num_shards=8, clock=None, sched=None):
    mem = AsymmetricMemory(num_hosts, sched=sched)
    return mem, ShardedLockTable(mem, num_shards=num_shards, clock=clock)


def key_homed_on(table, host, salt=""):
    for i in range(10_000):
        k = f"mode{salt}-{i}"
        if table.home_of(k) == host:
            return k
    raise AssertionError(f"no key homed on host {host}")


def tsum(table, field):
    return sum(r[field] for r in table.telemetry())


# ------------------------------------------------------------ shared grants
def test_local_reader_join_is_zero_rdma_single_cas():
    """The tentpole cost claim, local class: a home-host shared acquire is
    registers + one machine CAS — zero fabric operations."""
    mem, table = make_table()
    host = 1
    p = mem.spawn(host)
    k = key_homed_on(table, host)
    snap = p.counts.snapshot()
    lease = table.try_acquire(p, k, ttl=5.0, mode=SHARED)
    d = p.counts.delta(snap)
    assert lease is not None and lease.mode == SHARED
    assert d.rdma_ops == 0, vars(d)
    assert d.local_cas == 1  # the grant itself is a single CAS
    assert tsum(table, "grants_shared") == 1
    assert tsum(table, "shared_joins") == 1


def test_remote_reader_join_is_exactly_one_rcas():
    """The tentpole cost claim, remote class: one read doorbell + exactly
    one rCAS per shared acquire."""
    mem, table = make_table(num_hosts=2, num_shards=2)
    k = key_homed_on(table, 0)
    p = mem.spawn(1)
    snap = p.counts.snapshot()
    lease = table.try_acquire(p, k, ttl=5.0, mode=SHARED)
    d = p.counts.delta(snap)
    assert lease is not None
    assert d.remote_cas == 1, vars(d)
    assert d.remote_doorbell == 2  # one read posting + the CAS
    assert tsum(table, "shared_remote_grants") == 1
    assert tsum(table, "shared_acquire_rcas") == 1


def test_readers_stack_and_block_writers_until_drained():
    clock = FakeClock()
    mem, table = make_table(clock=clock)
    r1, r2, w = mem.spawn(0), mem.spawn(1), mem.spawn(2)
    a = table.try_acquire(r1, "doc", ttl=10.0, mode=SHARED)
    b = table.try_acquire(r2, "doc", ttl=10.0, mode=SHARED)
    assert a is not None and b is not None
    assert a.token == b.token  # one reader generation, one token
    # A writer cannot cut through a live cohort...
    assert table.try_acquire(w, "doc", ttl=10.0) is None
    # ...and the cohort only frees once EVERY reader has released.
    assert table.release(r1, a) is True
    assert table.try_acquire(w, "doc", ttl=10.0) is None
    assert table.release(r2, b) is True
    wl = table.try_acquire(w, "doc", ttl=10.0)
    assert wl is not None and wl.mode == EXCLUSIVE
    assert wl.token > a.token  # the writer's token fences the readers' gen


def test_writer_blocks_readers_while_live():
    clock = FakeClock()
    mem, table = make_table(clock=clock)
    w, r = mem.spawn(0), mem.spawn(1)
    wl = table.try_acquire(w, "k", ttl=10.0)
    assert wl is not None
    assert table.try_acquire(r, "k", ttl=10.0, mode=SHARED) is None
    assert tsum(table, "rejects_shared") == 1
    table.release(w, wl)
    assert table.try_acquire(r, "k", ttl=10.0, mode=SHARED) is not None


def test_shared_grant_over_expired_writer_reuses_token_next_writer_fences():
    clock = FakeClock()
    mem, table = make_table(clock=clock)
    w, r = mem.spawn(0), mem.spawn(1)
    wl = table.try_acquire(w, "k", ttl=5.0)
    clock.advance(5.0)  # writer crashed; lease lapses
    rl = table.try_acquire(r, "k", ttl=5.0, mode=SHARED)
    assert rl is not None
    assert rl.token == wl.token  # readers reuse the last allocated token
    assert tsum(table, "expirations") == 1
    # The zombie writer is fenced: its renewal and release both fail.
    clock.t = 4.0  # even with a rewound clock view, the word moved on
    assert table.renew(w, wl) is None
    assert table.release(w, wl) is False
    clock.t = 6.0
    # The next writer (after the reader leaves) allocates a LARGER token.
    assert table.release(r, rl) is True
    w2 = table.try_acquire(w, "k", ttl=5.0)
    assert w2 is not None and w2.token > wl.token


def test_shared_acquire_is_reentrant_by_stacking():
    mem, table = make_table()
    p = mem.spawn(0)
    a = table.try_acquire(p, "k", ttl=10.0, mode=SHARED)
    b = table.try_acquire(p, "k", ttl=10.0, mode=SHARED)
    assert a is not None and b is not None  # two cohort slots
    w = mem.spawn(1)
    assert table.try_acquire(w, "k", ttl=10.0) is None
    assert table.release(p, a) and table.release(p, b)
    assert table.try_acquire(w, "k", ttl=10.0) is not None


# ------------------------------------------------------ renew/release, shared
def test_shared_renew_extends_cohort_horizon():
    clock = FakeClock()
    mem, table = make_table(clock=clock)
    p = mem.spawn(0)
    lease = table.try_acquire(p, "k", ttl=5.0, mode=SHARED)
    clock.advance(4.0)
    renewed = table.renew(p, lease)
    assert renewed is not None and renewed.expires_at == 9.0
    assert renewed.token == lease.token
    assert tsum(table, "shared_renews") == 1
    clock.advance(6.0)  # past the renewed horizon
    assert table.renew(p, renewed) is None


def test_expired_shared_release_cannot_decrement_a_successor_generation():
    """The ABA guard: generations reuse the last token, so a zombie reader
    from generation N must not decrement generation N+1's cohort count."""
    clock = FakeClock()
    mem, table = make_table(clock=clock)
    z, r, w = mem.spawn(0), mem.spawn(1), mem.spawn(2)
    zombie = table.try_acquire(z, "k", ttl=5.0, mode=SHARED)
    clock.advance(5.0)  # generation N dies with the zombie in it
    succ = table.try_acquire(r, "k", ttl=10.0, mode=SHARED)
    assert succ is not None and succ.token == zombie.token  # token reused
    # The zombie's late release must NOT free the successor's slot...
    assert table.release(z, zombie) is False
    # ...so the live cohort still excludes writers.
    assert table.try_acquire(w, "k", ttl=10.0) is None
    assert table.release(r, succ) is True


def test_remote_shared_release_is_one_read_one_rcas():
    mem, table = make_table(num_hosts=2, num_shards=2)
    k = key_homed_on(table, 0)
    p = mem.spawn(1)
    lease = table.try_acquire(p, k, ttl=5.0, mode=SHARED)
    snap = p.counts.snapshot()
    assert table.release(p, lease) is True
    d = p.counts.delta(snap)
    assert d.remote_cas == 1 and d.remote_read == 1, vars(d)
    assert tsum(table, "shared_releases") == 1


def test_double_release_of_live_shared_lease_cannot_free_another_reader():
    """The cohort count is anonymous: a decrement cannot tell whose slot it
    takes, so the client slot ledger must refuse a release it does not own.
    Without it, A's double release frees B's live slot and a writer grants
    EXCLUSIVE beside reader B."""
    mem, table = make_table()
    a, b, w = mem.spawn(0), mem.spawn(1), mem.spawn(2)
    la = table.try_acquire(a, "dd", ttl=30.0, mode=SHARED)
    lb = table.try_acquire(b, "dd", ttl=30.0, mode=SHARED)
    assert la is not None and lb is not None
    assert table.release(a, la) is True
    assert table.release(a, la) is False      # second release: not A's slot
    assert table.renew(a, la) is None         # nor can A renew what it freed
    # B's slot is intact: the writer stays excluded until B releases.
    assert table.try_acquire(w, "dd", ttl=30.0) is None
    assert table.release(b, lb) is True
    assert table.try_acquire(w, "dd", ttl=30.0) is not None


def test_upgrade_consumes_the_reader_slot():
    """After an upgrade the old shared lease object is spent: releasing or
    renewing it must fail rather than decrement a later cohort's count."""
    mem, table = make_table()
    p = mem.spawn(0)
    shared = table.try_acquire(p, "up", ttl=30.0, mode=SHARED)
    up = table.upgrade(p, shared)
    assert up is not None
    assert table.release(p, shared) is False
    assert table.renew(p, shared) is None
    assert table.upgrade(p, shared) is None
    # The exclusive lease is fully operational and releases normally.
    assert table.release(p, up) is True


def test_release_batch_drops_duplicate_shared_leases():
    mem, table = make_table(num_hosts=2, num_shards=2)
    shard0 = [k for i in range(400)
              if table.shard_of(k := f"dup/{i}") == 0][:3]
    p = mem.spawn(1 - table.shards[0].home_host)  # remote to shard 0
    q = mem.spawn(table.shards[0].home_host)
    leases = [table.try_acquire(p, k, ttl=30.0, mode=SHARED) for k in shard0]
    others = [table.try_acquire(q, k, ttl=30.0, mode=SHARED) for k in shard0]
    assert all(leases) and all(others)
    # Duplicates in one batch: only the owned slots release (3, not 6).
    assert table.release_batch(p, leases + leases) == 3
    # The co-readers' slots survived the duplicate-laden batch.
    w = mem.spawn(1 - table.shards[0].home_host)
    assert table.try_acquire(w, shard0[0], ttl=5.0) is None
    assert all(table.release(q, o) for o in others)


# --------------------------------------------------------- writer drain
def test_writer_intent_barrier_drains_a_reader_cohort():
    """The drain protocol end-to-end: a blocked writer arms the barrier; new
    joins and shared renewals are refused; existing readers release; the
    writer grants (clearing the barrier) and readers resume afterwards."""
    clock = FakeClock()
    mem, table = make_table(clock=clock)
    r1, r2, w = mem.spawn(0), mem.spawn(1), mem.spawn(2)
    a = table.try_acquire(r1, "hot", ttl=10.0, mode=SHARED)
    assert a is not None
    # The writer's blocked attempt arms the intent barrier.
    assert table.try_acquire(w, "hot", ttl=10.0) is None
    # New joins are now refused (drain priority)...
    assert table.try_acquire(r2, "hot", ttl=10.0, mode=SHARED) is None
    assert tsum(table, "intent_blocks") >= 1
    # ...and the holder cannot extend the cohort's horizon either.
    assert table.renew(r1, a) is None
    # The holder keeps its slot until it releases (or expires)...
    assert table.try_acquire(w, "hot", ttl=10.0) is None
    assert table.release(r1, a) is True
    # ...after which the writer wins with a strictly larger token.
    wl = table.try_acquire(w, "hot", ttl=10.0)
    assert wl is not None and wl.token > a.token
    # The grant cleared the barrier: once the writer leaves, readers rejoin.
    assert table.release(w, wl) is True
    assert table.try_acquire(r2, "hot", ttl=10.0, mode=SHARED) is not None


def test_stale_intent_barrier_lapses_without_a_writer():
    """A writer that arms the barrier and then gives up must not wedge the
    key: the barrier is a deadline, not a flag, so it lapses on its own."""
    clock = FakeClock()
    mem, table = make_table(clock=clock)
    r1, r2, w = mem.spawn(0), mem.spawn(1), mem.spawn(2)
    a = table.try_acquire(r1, "k", ttl=5.0, mode=SHARED)
    assert table.try_acquire(w, "k", ttl=5.0) is None  # arms barrier @ eexp=5
    assert table.try_acquire(r2, "k", ttl=5.0, mode=SHARED) is None  # blocked
    table.release(r1, a)
    clock.advance(5.5)  # the writer never came back; the barrier lapsed
    assert table.try_acquire(r2, "k", ttl=5.0, mode=SHARED) is not None


# ------------------------------------------------------ upgrade / downgrade
def test_sole_reader_upgrades_with_strictly_larger_token():
    clock = FakeClock()
    mem, table = make_table(clock=clock)
    p = mem.spawn(0)
    shared = table.try_acquire(p, "k", ttl=10.0, mode=SHARED)
    up = table.upgrade(p, shared)
    assert up is not None and up.mode == EXCLUSIVE
    assert up.token > shared.token
    assert tsum(table, "upgrades") == 1
    # It is a real writer lease: renewable on the fast path, fences readers.
    r = mem.spawn(1)
    assert table.try_acquire(r, "k", ttl=10.0, mode=SHARED) is None
    assert table.renew(p, up) is not None
    assert table.release(p, up) is True


def test_upgrade_with_other_readers_arms_drain_and_waits():
    clock = FakeClock()
    mem, table = make_table(clock=clock)
    p, q = mem.spawn(0), mem.spawn(1)
    mine = table.try_acquire(p, "k", ttl=10.0, mode=SHARED)
    other = table.try_acquire(q, "k", ttl=10.0, mode=SHARED)
    assert table.upgrade(p, mine) is None  # cohort not drained
    # The attempt armed the drain barrier: no new readers pile in.
    r = mem.spawn(2)
    assert table.try_acquire(r, "k", ttl=10.0, mode=SHARED) is None
    table.release(q, other)
    up = table.upgrade(p, mine)
    assert up is not None and up.token > mine.token
    # Wrong-mode arguments are loud errors, not silent no-ops.
    with pytest.raises(ValueError):
        table.upgrade(p, up)
    with pytest.raises(ValueError):
        table.downgrade(p, mine)


def test_downgrade_is_single_cas_and_opens_the_cohort():
    clock = FakeClock()
    mem, table = make_table(clock=clock)
    host = 2
    p = mem.spawn(host)
    k = key_homed_on(table, host)
    wl = table.try_acquire(p, k, ttl=10.0)
    snap = p.counts.snapshot()
    down = table.downgrade(p, wl)
    d = p.counts.delta(snap)
    assert down is not None and down.mode == SHARED
    assert down.token == wl.token  # the generation keeps the writer's token
    assert d.local_cas == 1 and d.rdma_ops == 0, vars(d)  # one machine CAS
    assert tsum(table, "downgrades") == 1
    # Another reader can join the opened cohort immediately...
    q = mem.spawn(0)
    join = table.try_acquire(q, k, ttl=10.0, mode=SHARED)
    assert join is not None and join.token == wl.token
    # ...and the stale exclusive lease object is dead (witness moved on).
    assert table.release(p, wl) is False
    assert table.release(p, down) and table.release(q, join)


# ------------------------------------------------- batched release grouping
def test_release_batch_coalesces_a_shard_group_into_one_doorbell():
    """The satellite perf fix: releasing K same-shard exclusive leases from
    a remote client posts ONE doorbell (K CAS work requests), not K."""
    mem, table = make_table(num_hosts=2, num_shards=2)
    shard0 = [k for i in range(400)
              if table.shard_of(k := f"rb/{i}") == 0][:6]
    assert len(shard0) == 6
    p = mem.spawn(1 - table.shards[0].home_host)  # remote to shard 0
    leases = table.acquire_batch(p, shard0, ttl=30.0)
    snap = p.counts.snapshot()
    assert table.release_batch(p, leases) == 6
    d = p.counts.delta(snap)
    assert d.remote_doorbell == 1, vars(d)  # was 6 doorbells pre-grouping
    assert d.remote_cas == 6  # completions still account every witness CAS
    assert tsum(table, "fast_releases") == 6


def test_release_batch_mixed_modes_and_stale_leases():
    clock = FakeClock()
    mem, table = make_table(clock=clock)
    p = mem.spawn(0)
    excl = table.acquire_batch(p, [f"mx/{i}" for i in range(4)], ttl=10.0)
    shrd = [table.try_acquire(p, f"ms/{i}", ttl=10.0, mode=SHARED)
            for i in range(3)]
    assert all(shrd)
    stale = table.try_acquire(p, "mx/stale", ttl=1.0)
    clock.advance(2.0)  # `stale` lapses; a rival takes it over
    rival = mem.spawn(1)
    assert table.try_acquire(rival, "mx/stale", ttl=50.0) is not None
    n = table.release_batch(p, excl + shrd + [stale])
    assert n == len(excl) + len(shrd)  # everything but the fenced stale one
    # All released keys are grantable again.
    for lease in excl + shrd:
        assert table.try_acquire(p, lease.key, ttl=5.0) is not None


def test_release_batch_shared_remote_uses_two_doorbells():
    """Shared group releases: one read posting for every cohort word + one
    CAS posting for the decrements — not 2 per lease."""
    mem, table = make_table(num_hosts=2, num_shards=2)
    shard0 = [k for i in range(400)
              if table.shard_of(k := f"rs/{i}") == 0][:5]
    p = mem.spawn(1 - table.shards[0].home_host)
    leases = [table.try_acquire(p, k, ttl=30.0, mode=SHARED) for k in shard0]
    assert all(leases)
    snap = p.counts.snapshot()
    assert table.release_batch(p, leases) == 5
    d = p.counts.delta(snap)
    assert d.remote_doorbell == 2, vars(d)
    assert d.remote_cas == 5 and d.remote_read == 5


def test_release_batch_slow_path_takes_one_critical_section_per_shard():
    """Stale-witness exclusive leases (renewed since acquire) fall off the
    batched fast CAS; the slow remainder settles under ONE shard ALock."""
    clock = FakeClock()
    mem, table = make_table(num_hosts=2, num_shards=2, clock=clock)
    shard0 = [k for i in range(400)
              if table.shard_of(k := f"sl/{i}") == 0][:4]
    p = mem.spawn(table.shards[0].home_host)
    leases = table.acquire_batch(p, shard0, ttl=10.0)
    clock.advance(1.0)
    renewed = [table.renew(p, l) for l in leases]
    assert all(renewed)
    # Release with the ORIGINAL (stale-witness) objects: every fast CAS
    # loses, yet the batch still releases everything via the grouped CS.
    assert table.release_batch(p, leases) == 4
    for k in shard0:
        assert table.try_acquire(p, k, ttl=5.0) is not None


# ------------------------------------------------------- per-mode telemetry
def test_mode_class_totals_partition_the_class_totals():
    mem, table = make_table(num_hosts=2, num_shards=4)
    lo, rm = mem.spawn(0), mem.spawn(1)
    for i in range(6):
        k = f"pt/{i}"
        mode = SHARED if i % 2 else EXCLUSIVE
        p = lo if table.home_of(k) == 0 else rm
        lease = table.try_acquire(p, k, ttl=5.0, mode=mode)
        assert lease is not None
        table.release(p, lease)
    totals = table.class_totals()
    by_mode = table.mode_class_totals()
    for cls in (LOCAL, REMOTE):
        merged = by_mode[LeaseMode.SHARED][cls] + by_mode[LeaseMode.EXCLUSIVE][cls]
        assert vars(merged) == vars(totals[cls])
    rows = table.telemetry()
    assert sum(r["grants_shared"] + r["grants_exclusive"] for r in rows) \
        == sum(r["grants"] for r in rows) == 6


# ------------------------------------------------- service cache, per mode
def test_service_cache_is_keyed_by_mode_and_keeps_shared_fast_path():
    clock = FakeClock()
    svc = CoordinationService(num_hosts=2, num_shards=4, clock=clock)
    p = svc.host_process(0)
    first = svc.acquire(p, "cached", ttl=5.0, mode=LeaseMode.SHARED)
    clock.advance(1.0)
    assert svc.renew(p, first) is not None
    clock.advance(3.5)
    # 4.5s in: the ORIGINAL object has 0.5s left, but the cached witness
    # (renewed to 6.0) keeps the renewal valid well past that.
    clock.advance(1.0)  # now 5.5 > first.expires_at=5.0
    assert svc.renew(p, first) is not None  # stale object, fresh witness
    assert sum(r["shared_renews"] for r in svc.telemetry()) == 2
    # Release with the stale object also rides the cached witness.
    assert svc.release(p, first) is True
    assert svc.try_acquire(p, "cached", ttl=5.0) is not None  # fully free


def test_service_upgrade_downgrade_maintain_cache():
    clock = FakeClock()
    svc = CoordinationService(num_hosts=2, num_shards=4, clock=clock)
    p = svc.host_process(0)
    shared = svc.acquire(p, "k", ttl=5.0, mode=LeaseMode.SHARED)
    up = svc.upgrade(p, shared)
    assert up is not None and up.mode == LeaseMode.EXCLUSIVE
    clock.advance(1.0)
    assert svc.renew(p, up) is not None
    down = svc.downgrade(p, up)
    assert down is not None and down.mode == LeaseMode.SHARED
    clock.advance(1.0)
    assert svc.renew(p, down) is not None
    assert svc.release(p, down) is True


# ------------------------------------------------- admission: read vs write
def test_admission_read_lanes_stack_readers_and_quiesce_drains():
    adm = BatchAdmission(num_slots=2, ttl=30.0, read_slots=2)
    # Write slots are exclusive: 2 slots, third admit times out.
    w1, w2 = adm.admit(timeout=0.05), adm.admit(timeout=0.05)
    with pytest.raises(TimeoutError):
        adm.admit(timeout=0.05)
    # Read lanes are shared: many concurrent readers, no capacity consumed.
    readers = [adm.admit_read(timeout=0.05) for _ in range(6)]
    assert all(r.mode == LeaseMode.SHARED for r in readers)
    st = adm.stats()
    assert st["grants_shared"] == 6 and st["grants_exclusive"] == 2
    assert st["local_rdma_ops"] == 0  # the serving host is the local class
    for r in readers[:5]:
        assert adm.complete(r)
    # Quiesce the last reader's lane (from its own maintenance thread —
    # each server thread is its own coordination Process): the drain
    # barrier holds it out until the reader completes on ITS thread.
    lane_idx = int(readers[5].key.rsplit("readlane", 1)[1])
    out = {}

    def maintenance():
        out["lease"] = adm.quiesce(lane=lane_idx, timeout=10.0)

    t = threading.Thread(target=maintenance)
    t.start()
    time.sleep(0.05)  # let the quiesce block on the live reader
    assert "lease" not in out
    assert adm.complete(readers[5])  # reader leaves on the admitting thread
    t.join(timeout=10.0)
    maint = out["lease"]
    assert maint.mode == LeaseMode.EXCLUSIVE
    # Exclusive releases are witness CASes — any thread may complete them.
    assert adm.complete(maint)
    assert adm.complete(w1) and adm.complete(w2)


def test_admission_rejects_bad_read_slot_configs():
    adm = BatchAdmission(num_slots=1)
    with pytest.raises(ValueError):
        adm.admit_read()
    with pytest.raises(ValueError):
        adm.quiesce(lane=0)
    with pytest.raises(ValueError):
        BatchAdmission(num_slots=1, read_slots=-1)


# ------------------------------------------------------- mode API hygiene
def test_forged_shared_token_never_validates():
    clock = FakeClock()
    svc = CoordinationService(num_hosts=2, num_shards=4, clock=clock)
    p = svc.host_process(0)
    lease = svc.acquire(p, "k", ttl=5.0, mode=LeaseMode.SHARED)
    forged = dataclasses.replace(lease, token=lease.token + 7)
    assert svc.renew(p, forged) is None
    assert svc.release(p, forged) is False
    assert svc.release(p, lease) is True


@pytest.mark.parametrize("seed", [0, 1])
def test_sx_exclusion_under_threaded_stress(seed):
    """No-expiry regime (TTL >> test): a writer must never overlap a reader
    or another writer, while readers overlap freely — under the randomised
    preemption scheduler.

    All clients are HOME-host (machine CAS on the packed word, atomic under
    the register's machine lock), which is the regime where exclusion is
    airtight and the test can demand zero violations forever.  Mixing
    classes on one word is Table 1's non-atomic cell: a remote rCAS's split
    read/write phases can lose a concurrent local count update in a
    vanishing window, leaving a phantom (or short) cohort count — the
    documented lease posture applies (the phantom expires within one TTL,
    fencing keeps the residue harmless downstream), but a no-expiry stress
    test cannot wait for it."""
    import random as _random
    from repro.core import make_scheduler

    rng = _random.Random(seed)
    mem = AsymmetricMemory(1, sched=make_scheduler(rng, 0.15))
    table = ShardedLockTable(mem, num_shards=2)
    key = "stressed"
    state = {"readers": 0, "writers": 0, "max_readers": 0, "violations": 0}
    mu = threading.Lock()

    def worker(host, widx):
        p = mem.spawn(host)
        r = _random.Random(1000 * seed + widx)
        import time as _time
        for _ in range(20):
            if r.random() < 0.3:
                lease = table.acquire(p, key, ttl=1e9, timeout=60.0)
                with mu:
                    state["writers"] += 1
                    if state["writers"] != 1 or state["readers"] != 0:
                        state["violations"] += 1
                _time.sleep(0.001)  # hold: any overlap would be caught
                with mu:
                    if state["writers"] != 1 or state["readers"] != 0:
                        state["violations"] += 1
                    state["writers"] -= 1
                table.release(p, lease)
            else:
                lease = table.acquire(p, key, ttl=1e9, timeout=60.0,
                                      mode=SHARED)
                with mu:
                    state["readers"] += 1
                    state["max_readers"] = max(state["max_readers"],
                                               state["readers"])
                    if state["writers"] != 0:
                        state["violations"] += 1
                _time.sleep(0.001)  # readers overlap here by design
                with mu:
                    if state["writers"] != 0:
                        state["violations"] += 1
                    state["readers"] -= 1
                table.release(p, lease)

    ts = [threading.Thread(target=worker, args=(0, i)) for i in range(6)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert state["violations"] == 0, state
    assert state["max_readers"] >= 2, "readers never actually overlapped"


def test_acquire_batch_shared_mode_joins_every_key():
    mem, table = make_table()
    p, q = mem.spawn(0), mem.spawn(1)
    keys = [f"bs/{i}" for i in range(6)]
    mine = table.acquire_batch(p, keys, ttl=10.0, mode=SHARED)
    theirs = table.acquire_batch(q, keys, ttl=10.0, mode=SHARED)
    assert len(mine) == len(theirs) == 6  # cohorts, not conflicts
    w = mem.spawn(2)
    assert table.try_acquire(w, keys[0], ttl=5.0) is None
    assert table.release_batch(p, mine) == 6
    assert table.release_batch(q, theirs) == 6
    assert table.try_acquire(w, keys[0], ttl=5.0) is not None
