"""Fault-injection matrix: kill a holder at EVERY labeled crash point and
assert the stack recovers with zero fencing violations, zero zombie grants,
and recovery latency under the TTL — all in virtual time.

The sim runner itself raises on token regressions and zombie renews, so a
clean return already certifies fencing; the assertions below pin the
counters explicitly so a silently-weakened runner cannot pass.
"""

import json

import pytest

from repro.coord import CRASH_POINTS, FaultInjector, InflationPolicy
from repro.sim import run_lock_table_sim

TTL = 1e-3
# Aggressive thresholds so the matrix's hot keys actually inflate (and
# deflate) within the run — the inflate.mid / deflate.mid windows never
# arm under the default policy at this scale.
POLICY = InflationPolicy(inflate_retries=4, deflate_retries=1, window=1e-3,
                         min_inflated=5e-4, min_deflated=1e-4)
CFG = dict(num_hosts=8, clients_per_host=4, total_ops=3000, seed=5,
           failover_ttl=TTL, crash_warmup=2e-3, crash_spacing=TTL / 8,
           restart_delay=TTL / 8, inflation=POLICY)

# upgrade.mid is the rarest window (~19 arrivals in this config); keep its
# trigger early so the one-shot reliably fires.
_NTH = {"upgrade.mid": 3}


@pytest.mark.parametrize("label", CRASH_POINTS)
def test_holder_killed_at_crash_point_recovers(label):
    fi = FaultInjector().at(label, nth=_NTH.get(label, 5))
    r = run_lock_table_sim("crash_restart", fault=fi, **CFG)
    assert fi.fired, f"crash point {label} never armed in this workload"
    assert all(lab == label for lab, _pid, _n in fi.fired)
    # Fencing safety: no token ever moved backwards, no fenced-out zombie
    # renewed past its horizon.
    assert r.token_regressions == 0
    assert r.zombie_renews == 0
    # Liveness: injected crashes on top of the host-crash schedule still
    # leave the table serving grants, and restarted holders re-enter by
    # reclaiming inside the TTL instead of wedging on expiry.
    assert r.ops > 0 and r.crashes > 0
    if r.reclaims:
        assert r.recovery_max < TTL


def test_matrix_runs_are_seed_deterministic():
    label = "release.pre_cas"
    rows = []
    for _ in range(2):
        fi = FaultInjector().at(label, nth=5)
        r = run_lock_table_sim("crash_restart", fault=fi, **CFG)
        rows.append((json.dumps(r.row(), sort_keys=True), tuple(fi.fired)))
    assert rows[0] == rows[1]


def test_crash_cell_crossed_with_fabric_loss():
    # The matrix's new axis: one injector arms a process-death window AND
    # the fabric's message-loss points, so a holder dies at release.pre_cas
    # while the surrounding traffic is losing, duplicating, and delaying
    # postings — the recovery path must hold under both at once.
    fi = (FaultInjector()
          .at("release.pre_cas", nth=5)
          .at("fabric.drop", nth=3)
          .at("fabric.dup", nth=7)
          .at("fabric.delay", nth=11))
    r = run_lock_table_sim("crash_restart", fault=fi, **CFG)
    labels = {lab for lab, _pid, _n in fi.fired}
    assert "release.pre_cas" in labels, "the crash cell never armed"
    assert {"fabric.drop", "fabric.dup", "fabric.delay"} <= labels, \
        f"fabric cells never armed: {labels}"
    # The lossy fabric actually exercised the timeout/retry machinery...
    assert r.fabric["drops"] >= 1 and r.fabric["dups"] >= 1
    assert r.fabric["delays"] >= 1
    # ...and neither fault axis broke fencing or liveness.
    assert r.token_regressions == 0
    assert r.zombie_renews == 0
    assert r.ops > 0 and r.crashes > 0
    if r.reclaims:
        assert r.recovery_max < TTL


def test_crossed_cells_are_seed_deterministic():
    rows = []
    for _ in range(2):
        fi = (FaultInjector()
              .at("grant.pre_ledger", nth=4)
              .at("fabric.drop", nth=2))
        r = run_lock_table_sim("crash_restart", fault=fi, **CFG)
        rows.append((json.dumps(r.row(), sort_keys=True), tuple(fi.fired),
                     tuple(sorted(r.fabric.items()))))
    assert rows[0] == rows[1]


def test_seeded_crash_storm_stays_safe():
    # Beyond one-shots: a Bernoulli storm over every label at once.
    fi = FaultInjector.seeded(21, prob=0.002)
    r = run_lock_table_sim("crash_restart", fault=fi, **CFG)
    assert fi.fired  # the storm actually bit
    assert r.token_regressions == 0 and r.zombie_renews == 0
    if r.reclaims:
        assert r.recovery_max < TTL
