"""Explicit-state model check of the PlusCal spec (paper Appendix A)."""

import pytest

from repro.core.modelcheck import check


@pytest.mark.parametrize("np_,b", [(2, 1), (2, 2), (3, 1), (3, 2)])
def test_paper_spec_holds(np_, b):
    r = check(num_procs=np_, init_budget=b)
    assert r.mutual_exclusion, r.violations
    assert r.deadlock_free, r.violations
    assert r.starvation_free, r.violations
    assert r.num_states > 100


def test_state_space_is_exhaustive_and_stable():
    # Exact state counts pin the transition system against silent edits.
    assert check(2, 1).num_states == check(2, 2).num_states == 692


def test_seeded_bug_skip_global_breaks_mutual_exclusion():
    r = check(num_procs=2, init_budget=1, variant="skip_global")
    assert not r.mutual_exclusion
    assert "mutual_exclusion" in r.violations


def test_seeded_bug_no_decrement_starves():
    """Without the budget decrement the same class passes the lock forever:
    the checker must find a fair cycle where the other class waits."""
    r = check(num_procs=3, init_budget=1, variant="no_decrement")
    assert r.mutual_exclusion          # safety still holds
    assert not r.starvation_free       # liveness broken
    assert "starvation" in r.violations
