"""End-to-end behaviour: loss decreases, checkpoints resume deterministically,
serving generates; multi-device training equivalences run in subprocesses."""

import numpy as np
import pytest

from repro.configs import RunConfig, ShapeConfig
from repro.launch.serve import serve
from repro.launch.train import train


def test_training_reduces_loss(tmp_path):
    run = RunConfig(
        learning_rate=5e-3, warmup_steps=5, total_steps=80,
        checkpoint_every=1000, checkpoint_dir=str(tmp_path),
    )
    out = train(
        "llama3.2-1b", smoke=True, steps=80,
        shape=ShapeConfig("e2e", seq_len=64, global_batch=8, kind="train"),
        run=run, log_every=10,
    )
    losses = [h["loss"] for h in out["history"]]
    assert losses[-1] < losses[0] - 0.5, f"no learning: {losses}"


def test_resume_is_deterministic(tmp_path):
    shape = ShapeConfig("e2e", seq_len=32, global_batch=4, kind="train")

    def mk_run(d):
        return RunConfig(learning_rate=5e-4, warmup_steps=2, total_steps=20,
                         checkpoint_every=10, checkpoint_dir=str(d))

    # uninterrupted 20 steps
    full = train("llama3.2-1b", steps=20, shape=shape, run=mk_run(tmp_path / "a"),
                 log_every=20)
    # interrupted at 10, resumed to 20
    train("llama3.2-1b", steps=10, shape=shape, run=mk_run(tmp_path / "b"),
          log_every=20)
    resumed = train("llama3.2-1b", steps=20, shape=shape,
                    run=mk_run(tmp_path / "b"), resume=True, log_every=20)
    a = full["history"][-1]["loss"]
    b = resumed["history"][-1]["loss"]
    assert abs(a - b) < 2e-3, f"resume diverged: {a} vs {b}"


def test_serving_generates_tokens():
    out = serve("llama3.2-1b", smoke=True, batch=2, prompt_len=16, gen_len=8)
    assert out["tokens"].shape == (2, 8)
    assert out["tokens"].dtype.kind == "i"


def test_moe_arch_trains(tmp_path):
    run = RunConfig(learning_rate=1e-3, warmup_steps=2, total_steps=20,
                    checkpoint_every=1000, checkpoint_dir=str(tmp_path))
    out = train(
        "deepseek-v2-236b", smoke=True, steps=20,
        shape=ShapeConfig("e2e", seq_len=32, global_batch=4, kind="train"),
        run=run, log_every=4,
    )
    losses = [h["loss"] for h in out["history"]]
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]


@pytest.mark.slow
def test_sync_equals_flat_on_multipod_mesh(multidevice):
    """Cohort schedule (sync) must be numerically identical to the flat
    paper-baseline; budgeted local mode must diverge only between syncs."""
    out = multidevice(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.compat import set_mesh
from repro.configs import get_config, ShapeConfig, RunConfig
from repro.models import Model, input_specs
from repro.launch.mesh import make_mesh
from repro.launch.steps import build_train_step, init_train_state

res = {}
for mode in ['flat', 'sync']:
    mesh = make_mesh((2,2,2), ('pod','data','model'))
    cfg = get_config('llama3.2-1b', smoke=True).with_overrides(dtype='float32')
    run = RunConfig(sync_mode=mode, total_steps=10)
    model = Model(cfg)
    shp = ShapeConfig('t', 32, 4, 'train')
    with set_mesh(mesh):
        step, shapes, state_sh, batch_sh = build_train_step(model, run, mesh, shp)
        state = jax.device_put(init_train_state(model, run, jax.random.PRNGKey(0), 2), state_sh)
        batch = jax.device_put(input_specs(cfg, shp, concrete=True, dtype=jnp.float32), batch_sh)
        ls = []
        for i in range(3):
            state, metrics = step(state, batch)
            ls.append(float(metrics['loss']))
    res[mode] = ls
# identical math, different collective schedules: equal to fp32 tolerance
np.testing.assert_allclose(res['flat'], res['sync'], rtol=1e-5)
print('OK', res)
""",
        devices=8,
    )
    assert "OK" in out


@pytest.mark.slow
def test_int8_compressed_sync_close_to_exact(multidevice):
    out = multidevice(
        """
import jax, jax.numpy as jnp
from repro.compat import set_mesh
from repro.configs import get_config, ShapeConfig, RunConfig
from repro.models import Model, input_specs
from repro.launch.mesh import make_mesh
from repro.launch.steps import build_train_step, init_train_state

res = {}
for mode, extra in [('sync', {}), ('sync', {'compress_int8': True})]:
    mesh = make_mesh((2,2,2), ('pod','data','model'))
    cfg = get_config('llama3.2-1b', smoke=True)
    run = RunConfig(sync_mode=mode, total_steps=10, **extra)
    model = Model(cfg)
    shp = ShapeConfig('t', 32, 4, 'train')
    with set_mesh(mesh):
        step, shapes, state_sh, batch_sh = build_train_step(model, run, mesh, shp)
        state = jax.device_put(init_train_state(model, run, jax.random.PRNGKey(0), 2), state_sh)
        batch = jax.device_put(input_specs(cfg, shp, concrete=True), batch_sh)
        for i in range(3):
            state, metrics = step(state, batch)
    res['int8' if extra else 'exact'] = float(metrics['loss'])
diff = abs(res['int8'] - res['exact'])
assert diff < 5e-3, res
print('OK', res)
""",
        devices=8,
    )
    assert "OK" in out


@pytest.mark.slow
def test_microbatched_grads_match_full_batch(multidevice):
    out = multidevice(
        """
import jax, jax.numpy as jnp
from repro.compat import set_mesh
from repro.configs import get_config, ShapeConfig, RunConfig
from repro.models import Model, input_specs
from repro.launch.mesh import make_mesh
from repro.launch.steps import build_train_step, init_train_state

res = {}
for mb in [1, 4]:
    mesh = make_mesh((2,2), ('data','model'))
    cfg = get_config('llama3.2-1b', smoke=True).with_overrides(dtype='float32')
    run = RunConfig(sync_mode='flat', total_steps=10, microbatches=mb)
    model = Model(cfg)
    shp = ShapeConfig('t', 32, 8, 'train')
    with set_mesh(mesh):
        step, shapes, state_sh, batch_sh = build_train_step(model, run, mesh, shp)
        state = jax.device_put(init_train_state(model, run, jax.random.PRNGKey(0)), state_sh)
        batch = jax.device_put(input_specs(cfg, shp, concrete=True, dtype=jnp.float32), batch_sh)
        state, metrics = step(state, batch)
    res[mb] = float(metrics['grad_norm'])
assert abs(res[1] - res[4]) / res[1] < 1e-3, res
print('OK', res)
""",
        devices=4,
    )
    assert "OK" in out
