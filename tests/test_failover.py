"""Failover tests: ``takeover_shard`` unit-level (guard refusal, witness
gate, epoch race, confirm-dead abort, intact-carry vs reset) plus small
deterministic ``home_death`` / ``partition`` workload smokes.

The unit tests drive the takeover by hand on a 4-host sim fabric with a
stub membership, so each abort path is exercised in isolation; the smokes
run the full stack (heartbeats, monitors, killer, verifier) at 8 hosts —
the 128-host acceptance numbers live in the benchmark sweep.
"""

import json

import pytest

from repro.coord import LeaseMode, LedgerStore, RecoverableClient
from repro.sim import SimEngine, run_lock_table_sim
from repro.sim.fabric import FabricFaults, FabricLatency, SimFabricMemory
from repro.coord.table import ShardedLockTable

TTL = 1e-3


class _StubMembership:
    """Duck-typed membership for takeover_shard: scripted verdicts."""

    def __init__(self, serve=True, dead=True):
        self.serve = serve
        self.dead = dead

    def can_serve(self):
        return self.serve

    def confirm_dead(self, host):
        return self.dead


class _Cluster:
    def __init__(self, num_hosts=4, num_shards=8, seed=0):
        self.engine = SimEngine(seed)
        self.faults = FabricFaults(seed=seed)
        self.mem = SimFabricMemory(num_hosts, self.engine, FabricLatency(),
                                   faults=self.faults)
        self.table = ShardedLockTable(
            self.mem, num_shards=num_shards, clock=self.engine.clock,
            sleep=self.engine.sleep_inline, name=f"sim{seed}")
        self.store = LedgerStore()

    def client(self, host, name):
        p = self.mem.spawn(host)
        return RecoverableClient(self.table, p, self.store.ledger(name))

    def key_homed_on(self, host, salt="t"):
        for i in range(50_000):
            k = f"fo/{salt}/{i}"
            if self.table.home_of(k) == host:
                return k
        raise RuntimeError("no key found")


class TestTakeoverShard:
    DEAD_HOME = 1

    def _cluster(self):
        c = _Cluster()
        self.shard_idx = self.dead_shard = next(
            s.index for s in c.table.shards if s.home_host == self.DEAD_HOME)
        return c

    def test_successor_must_be_a_new_home(self):
        c = self._cluster()
        p1 = c.mem.spawn(self.DEAD_HOME)
        with pytest.raises(ValueError, match="new home"):
            c.table.takeover_shard(p1, self.shard_idx, [])

    def test_partition_guard_refuses_without_quorum(self):
        c = self._cluster()
        p2 = c.mem.spawn(2)
        shard = c.table.shards[self.shard_idx]
        rep = c.table.takeover_shard(p2, self.shard_idx, [],
                                     membership=_StubMembership(serve=False))
        assert rep is None
        assert shard.takeover_refusals == 1
        assert shard.home_host == self.DEAD_HOME  # nothing moved

    def test_unreachable_witness_aborts_without_burning_an_epoch(self):
        c = self._cluster()
        shard = c.table.shards[self.shard_idx]
        witness = (self.DEAD_HOME + 1) % 4
        c.faults.fail_host(witness, 0.0)
        p3 = c.mem.spawn(3)  # NOT the witness: the probe must go remote
        rep = c.table.takeover_shard(p3, self.shard_idx, [],
                                     membership=_StubMembership())
        assert rep is None
        assert shard.takeover_aborts == 1
        assert shard.home_host == self.DEAD_HOME
        assert shard.epoch == 0

    def test_losing_the_epoch_cas_aborts(self, monkeypatch):
        c = self._cluster()
        shard = c.table.shards[self.shard_idx]
        rival = c.mem.spawn(3)
        orig = c.mem.auto_read

        def hijack(p, reg):
            v = orig(p, reg)
            if reg is shard.epoch_reg:
                # A rival successor wins the bump between our read and CAS.
                assert c.mem.auto_cas(rival, reg, v, v + 1) == v
            return v

        monkeypatch.setattr(c.mem, "auto_read", hijack)
        p2 = c.mem.spawn(2)
        rep = c.table.takeover_shard(p2, self.shard_idx, [],
                                     membership=_StubMembership())
        assert rep is None
        assert shard.takeover_aborts == 1
        assert shard.home_host == self.DEAD_HOME

    def test_confirm_dead_abort_burns_the_epoch_harmlessly(self):
        c = self._cluster()
        shard = c.table.shards[self.shard_idx]
        p2 = c.mem.spawn(2)
        rep = c.table.takeover_shard(p2, self.shard_idx, [],
                                     membership=_StubMembership(dead=False))
        assert rep is None
        assert shard.takeover_aborts == 1
        # The register epoch burned; the python-side mirror (what fencing
        # compares against) only advances on commit.
        assert c.mem.auto_read(p2, shard.epoch_reg) == 1
        assert shard.epoch == 0
        assert shard.home_host == self.DEAD_HOME
        # A later attempt wins from the burned register value.
        rep = c.table.takeover_shard(p2, self.shard_idx, [],
                                     membership=_StubMembership())
        assert rep is not None and rep["epoch"] == 2
        assert shard.home_host == 2 and shard.epoch == 2

    def test_rebuild_carries_live_exclusive_and_resets_the_rest(self):
        c = self._cluster()
        holder = c.client(3, "holder")
        churner = c.client(0, "churner")
        live_key = c.key_homed_on(self.DEAD_HOME, "live")
        dead_key = c.key_homed_on(self.DEAD_HOME, "done")
        assert c.table.shard_of(live_key) == self.shard_idx or True
        lease = holder.try_acquire(live_key, 10 * TTL)
        assert lease is not None and lease.mode == LeaseMode.EXCLUSIVE
        gone = churner.try_acquire(dead_key, 10 * TTL)
        assert gone is not None
        churner.release(gone)
        # The home dies; its successor folds every surviving ledger.
        c.faults.fail_host(self.DEAD_HOME, c.engine.clock.now)
        p2 = c.mem.spawn(2)
        reports = {}
        for s in list(c.table.shards):
            if s.home_host != self.DEAD_HOME:
                continue
            rep = c.table.takeover_shard(p2, s.index,
                                         c.store.all_records(),
                                         membership=_StubMembership())
            assert rep is not None
            reports[s.index] = rep
        assert sum(r["intact"] for r in reports.values()) == 1
        assert sum(r["reset"] for r in reports.values()) == 1
        # The carried lease survived the re-homing: the holder renews
        # against the NEW home's word with its old token.
        renewed = holder.renew(lease, 10 * TTL)
        assert renewed is not None and renewed.token == lease.token
        # The reset key is grantable under an advanced fence: no token
        # the dead home ever issued can collide with the new grant.
        again = churner.try_acquire(dead_key, TTL)
        assert again is not None
        assert again.token > gone.token
        for s in c.table.shards:
            assert s.home_host != self.DEAD_HOME


class TestFailoverSmokes:
    HD_CFG = dict(num_hosts=8, clients_per_host=2, num_shards=16,
                  total_ops=1500, failover_ttl=TTL)

    def test_home_death_rehomes_and_stays_deterministic(self):
        rows = []
        for _ in range(2):
            r = run_lock_table_sim("home_death", seed=3, **self.HD_CFG)
            rows.append(json.dumps(r.row(), sort_keys=True))
            assert r.takeovers > 0 and r.rehomed_keys > 0
            assert r.token_regressions == 0 and r.zombie_renews == 0
            assert r.detect_p99 > 0 and r.failover_p99 > 0
            assert r.failover_events
        assert rows[0] == rows[1]

    def test_partition_starves_the_minority(self):
        r = run_lock_table_sim("partition", seed=3, **self.HD_CFG)
        assert r.minority_grants == 0
        assert r.takeover_refusals > 0
        assert r.quorum_losses > 0 and r.guard_blocks > 0
        assert r.token_regressions == 0 and r.zombie_renews == 0
        # The majority side kept serving through the cut.
        assert r.ops >= self.HD_CFG["total_ops"]
