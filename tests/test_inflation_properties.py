"""Inflation property tests (inline fuzz — no hypothesis in the image).

Three properties the inflation machinery must preserve, checked under
seeded randomized schedules rather than example traces:

* **Fencing monotonicity + mutual exclusion**: across inflate, direct
  handoff, deflate, and expiry, every EXCLUSIVE grant on a key carries a
  strictly larger fencing token than every earlier grant on that key, and
  never lands while an unexpired, unreleased grant is outstanding.
* **No grant lost**: a queue that has waiters keeps producing grants —
  after the fuzz run the table drives to quiescence with every client able
  to acquire and release the hot key again.
* **Hysteresis bounds flapping**: an adversary that heats and cools a key
  as fast as the protocol allows cannot force more than one
  inflate+deflate pair per ``min_inflated + min_deflated`` of virtual
  time.

Crash-reclaim interaction (ledgers + restart) is exercised through the
sim's ``crash_restart`` workload with an aggressive policy: the runner
itself asserts fencing, and the counters are pinned here.
"""

import random

import pytest

from repro.core import AsymmetricMemory
from repro.coord import InflationPolicy, ShardedLockTable
from repro.sim import run_lock_table_sim


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


AGGRESSIVE = InflationPolicy(inflate_retries=3, deflate_retries=1,
                             window=1e-3, min_inflated=2e-3,
                             min_deflated=1e-3)


def _key_homed_on(table, host):
    for i in range(10_000):
        k = f"fuzz-{i}"
        if table.home_of(k) == host:
            return k
    raise AssertionError(f"no key homed on host {host}")


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fuzz_fencing_monotonic_and_no_duplicate_grants(seed):
    """Randomized clients on one hot key through full inflate/deflate/expiry
    cycles: token order is total, grants never overlap, nothing wedges."""
    rng = random.Random(seed)
    clock = FakeClock(1.0)
    mem = AsymmetricMemory(3)
    table = ShardedLockTable(mem, num_shards=3, clock=clock,
                             inflation=AGGRESSIVE, seed=seed)
    key = _key_homed_on(table, 0)
    shard = table.shards[table.shard_of(key)]
    # A mixed population: home-host clients (local cohort) + two remote
    # hosts (remote cohort) — both queue classes participate.
    clients = [mem.spawn(n) for n in (0, 0, 1, 1, 2, 2)]
    held = {}          # pid -> lease (still considered live by its owner)
    last_token = 0
    grants = 0
    TTL = 2e-3

    for step in range(4000):
        p = rng.choice(clients)
        now = clock()
        lease = held.get(p.pid)
        roll = rng.random()
        if lease is None:
            got = table.try_acquire(p, key, ttl=TTL)
            if got is not None:
                grants += 1
                # Monotonic fencing: strictly larger than every prior grant.
                assert got.token > last_token, (
                    f"token regression at step {step}: "
                    f"{got.token} <= {last_token}")
                last_token = got.token
                # No duplicated grant: every other outstanding lease must
                # have lapsed (expiry is the only way to override a holder
                # that never released — e.g. our simulated amnesiacs).
                for other in held.values():
                    assert other.expires_at <= now, (
                        f"overlapping grants at step {step}: "
                        f"{got.token} over live {other.token}")
                held[p.pid] = got
        elif roll < 0.70:
            table.release(p, lease)
            del held[p.pid]
        elif roll < 0.78:
            renewed = table.renew(p, lease, ttl=TTL)
            if renewed is not None:
                held[p.pid] = renewed
        elif roll < 0.85:
            del held[p.pid]  # amnesiac holder: the lease must expire out
        # Mostly tiny steps (heat), occasionally a long cool-off.
        clock.advance(rng.choice((2e-5, 2e-5, 2e-5, 1e-4, 3e-3)))

    assert grants > 200, f"fuzz stalled: only {grants} grants"
    assert shard.inflations > 0, "hot key never inflated — fuzz too cold"

    # No grant lost: drive to quiescence — every client can still take and
    # release the key (bounded polling; a lost queue grant would wedge it).
    for pid, lease in list(held.items()):
        proc = next(c for c in clients if c.pid == pid)
        table.release(proc, lease)
        del held[pid]
    for p in clients:
        got = None
        for _ in range(200):
            got = table.try_acquire(p, key, ttl=TTL)
            if got is not None:
                break
            clock.advance(1e-4)
        assert got is not None, f"client p{p.pid} can no longer acquire"
        assert got.token > last_token
        last_token = got.token
        assert table.release(p, got)


@pytest.mark.parametrize("seed", [11, 12])
def test_crash_reclaim_with_inflation_keeps_fencing(seed):
    """Ledger-writing clients + host crashes + restart reclaim, with keys
    inflating and deflating underneath: zero fencing violations."""
    ttl = 1e-3
    r = run_lock_table_sim(
        "crash_restart", num_hosts=8, clients_per_host=4, total_ops=3000,
        seed=seed, failover_ttl=ttl, crash_warmup=2e-3, crash_spacing=ttl / 8,
        restart_delay=ttl / 8,
        inflation=InflationPolicy(inflate_retries=4, deflate_retries=1,
                                  window=1e-3, min_inflated=5e-4,
                                  min_deflated=1e-4))
    assert r.token_regressions == 0
    assert r.zombie_renews == 0
    assert r.ops == 3000 and r.crashes > 0
    if r.reclaims:
        assert r.recovery_max < ttl


def test_hysteresis_bounds_flapping():
    """An adversary heating and cooling the key as fast as the protocol
    allows gets at most one inflate+deflate pair per
    ``min_inflated + min_deflated`` of virtual time."""
    pol = AGGRESSIVE
    clock = FakeClock(1.0)
    mem = AsymmetricMemory(2)
    table = ShardedLockTable(mem, num_shards=2, clock=clock, inflation=pol)
    key = _key_homed_on(table, 0)
    shard = table.shards[table.shard_of(key)]
    holder, hammer = mem.spawn(0), mem.spawn(1)
    t0 = clock()

    for _cycle in range(64):
        if clock() - t0 > 8 * (pol.min_inflated + pol.min_deflated):
            break
        # HEAT: hold the key and hammer it with minimal clock motion until
        # the estimator trips (or the refractory gap refuses — keep going).
        lease = None
        for _ in range(400):
            lease = table.try_acquire(holder, key, ttl=10.0)
            if lease is not None:
                break
            clock.advance(1e-5)
        assert lease is not None
        st = table.shards[table.shard_of(key)].keys[key]
        for _ in range(200):
            if st.infl is not None:
                break
            table.try_acquire(hammer, key, ttl=10.0)
            clock.advance(1e-5)
        table.release(holder, lease)
        if st.infl is None:
            continue  # refractory gap held: this cycle couldn't re-inflate
        # COOL: take the queue grant, go silent, and release repeatedly —
        # deflation is attempted at every release, the residency floor
        # refuses until min_inflated has truly elapsed.
        for _ in range(400):
            if st.infl is None:
                break
            got = None
            for _ in range(50):
                got = table.try_acquire(hammer, key, ttl=10.0)
                if got is not None:
                    break
                clock.advance(2e-5)
            if got is None:
                break
            clock.advance(2e-4)  # silence: the window drains
            table.release(hammer, got)

    elapsed = clock() - t0
    bound = elapsed / (pol.min_inflated + pol.min_deflated) + 1
    assert shard.inflations >= 2, "adversary never flapped — test is vacuous"
    assert shard.inflations <= bound, (
        f"flapping: {shard.inflations} inflations in {elapsed:.4f}s "
        f"(bound {bound:.1f})")
    assert shard.deflations <= shard.inflations
