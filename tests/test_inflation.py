"""Contention-adaptive lock inflation: unit tests.

Covers the mode-bit encoding, the windowed contention estimator and its
hysteresis, the split-phase inflated-key queue (including the direct lock
handoff payload), and the table-level inflate -> queue -> direct handoff ->
deflate lifecycle under a deterministic clock.
"""

import pytest

from repro.core import AsymmetricMemory
from repro.core.mcs import LOCAL_COHORT, REMOTE_COHORT, InflatedKeyQueue
from repro.coord import InflationPolicy, ShardedLockTable
from repro.coord.inflation import ContentionEstimator
from repro.coord.table import _INFL_RESERVE, _dec, _enc, _infl, _trusted


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


# ---------------------------------------------------------------- encoding
def test_mode_encoding_roundtrip():
    for count in (0, 1, 7, 1 << 30):
        for inflated in (False, True):
            word = _enc(count, inflated)
            assert _dec(word) == count
            assert _infl(word) is inflated
    # Deflated zero and inflated zero are distinct words.
    assert _enc(0, False) == 0
    assert _enc(0, True) == -1


def test_trusted_is_mode_aware():
    # Deflated: exact fence match only.
    assert _trusted(5, 5, _enc(0, False))
    assert not _trusted(4, 5, _enc(0, False))
    # Inflated: direct-handoff tokens run UNDER the epoch ceiling.
    assert _trusted(4, 5 + _INFL_RESERVE, _enc(0, True))
    assert not _trusted(6 + _INFL_RESERVE, 5 + _INFL_RESERVE, _enc(0, True))
    # Post-deflation word under a still-raised fence: untrusted on purpose.
    assert not _trusted(4, 5 + _INFL_RESERVE, _enc(0, False))


# ------------------------------------------------------------------ policy
def test_policy_validates_hysteresis_band():
    with pytest.raises(ValueError):
        InflationPolicy(inflate_retries=4, deflate_retries=4)
    with pytest.raises(ValueError):
        InflationPolicy(inflate_retries=0)
    with pytest.raises(ValueError):
        InflationPolicy(min_inflated=-1.0)
    with pytest.raises(ValueError):
        InflationPolicy(stale_after_ttls=0.0)


def test_estimator_threshold_and_window_decay():
    pol = InflationPolicy(inflate_retries=4, deflate_retries=1, window=1e-3)
    est = ContentionEstimator(pol)
    assert not est.should_inflate("k", 0.0)
    for _ in range(3):
        est.note("k", 0.0)
    assert not est.should_inflate("k", 0.0)  # 3 < 4
    est.note("k", 0.0)
    assert est.should_inflate("k", 0.0)      # at threshold
    # Two windows later the events have decayed out entirely.
    assert est.rate("k", 2.5e-3) == 0.0
    assert not est.should_inflate("k", 2.5e-3)


def test_estimator_hysteresis_floors():
    pol = InflationPolicy(inflate_retries=2, deflate_retries=1, window=1e-3,
                          min_inflated=5e-3, min_deflated=2e-3)
    est = ContentionEstimator(pol)
    est.mark_inflated("k", 1.0)
    # Residency floor: cold or not, no deflation before min_inflated.
    assert not est.should_deflate("k", 1.0 + 4e-3)
    assert est.should_deflate("k", 1.0 + 6e-3)
    est.mark_deflated("k", 2.0)
    for _ in range(8):
        est.note("k", 2.0 + 1e-4)
    # Refractory gap: hot again, but re-inflation must wait min_deflated.
    assert not est.should_inflate("k", 2.0 + 1e-3)
    for _ in range(8):
        est.note("k", 2.0 + 2.05e-3)  # still hot once the gap has passed
    assert est.should_inflate("k", 2.0 + 2.1e-3)


# ------------------------------------------------------- split-phase queue
def _queue(init_budget=4):
    mem = AsymmetricMemory(2)
    q = InflatedKeyQueue(mem, home_node=0, init_budget=init_budget, name="iq")
    return mem, q


def test_queue_cohort_split_by_node():
    mem, q = _queue()
    assert q.cid_of(mem.spawn(0)) == LOCAL_COHORT
    assert q.cid_of(mem.spawn(1)) == REMOTE_COHORT


def test_enqueue_leader_and_fifo_polling():
    mem, q = _queue()
    p1, p2 = mem.spawn(0), mem.spawn(0)
    assert q.enqueue(p1) is True          # empty queue: leader, entitled
    assert q.enqueue(p2) is False         # parked behind p1
    assert q.poll(p1) == "entitled"
    assert q.poll(p2) == "parked"
    assert not q.empty(p1)
    q.release(p1)                         # plain entitlement pass
    assert q.poll(p2) == "entitled"
    assert q.release(p2) is True          # cohort drained
    assert q.empty(p2)


def test_direct_handoff_payload_rides_the_budget_write():
    mem, q = _queue(init_budget=4)
    p1, p2 = mem.spawn(0), mem.spawn(0)
    q.enqueue(p1)
    q.enqueue(p2)
    assert q.take_grant(p2) is None       # nothing pending yet
    assert q.can_direct(p1)
    q.pass_grant(p1, token=7, expires_at=9.5)
    assert q.poll(p2) == "granted"
    assert q.take_grant(p2) == (7, 9.5)
    # Budget share was handed down alongside (4 - 1), and later polls see
    # a plain entitlement again.
    assert q.poll(p2) == "entitled"
    assert q.cohorts[LOCAL_COHORT].q_granted(p2) == 3


def test_can_direct_refuses_without_successor():
    mem, q = _queue()
    p1 = mem.spawn(0)
    q.enqueue(p1)
    assert not q.can_direct(p1)


def test_can_direct_defers_to_waiting_other_cohort_on_exhausted_budget():
    mem, q = _queue(init_budget=1)
    p1, p2 = mem.spawn(0), mem.spawn(0)
    remote = mem.spawn(1)
    q.enqueue(p1)
    q.enqueue(p2)
    # Budget 1: the handoff would land at 0.  Alone, that is still fine...
    assert q.can_direct(p1)
    # ...but not while the other cohort has a waiter — its turn.
    q.enqueue(remote)
    assert not q.can_direct(p1)


# ----------------------------------------------------- table-level lifecycle
AGGRESSIVE = InflationPolicy(inflate_retries=2, deflate_retries=1,
                             window=1e-3, min_inflated=0.0, min_deflated=0.0)


def _inflated_table(clock=None, num_hosts=2):
    clock = clock or FakeClock()
    mem = AsymmetricMemory(num_hosts)
    table = ShardedLockTable(mem, num_shards=num_hosts, clock=clock,
                             inflation=AGGRESSIVE)
    return mem, table, clock


def _key_homed_on(table, host):
    for i in range(10_000):
        k = f"hot-{i}"
        if table.home_of(k) == host:
            return k
    raise AssertionError(f"no key homed on host {host}")


def _inflate_key(mem, table, clock, key, holder, contender):
    """Drive the key hot: holder holds, contender bangs until inflation."""
    lease = table.try_acquire(holder, key, ttl=10.0)
    assert lease is not None and not lease.inflated
    for _ in range(50):
        st = table.shards[table.shard_of(key)].keys[key]
        if st.infl is not None:
            break
        assert table.try_acquire(contender, key, ttl=10.0) is None
    st = table.shards[table.shard_of(key)].keys[key]
    assert st.infl is not None, "key never inflated under hammering"
    return lease, st


def test_key_inflates_under_contention_and_holder_still_releases():
    mem, table, clock = _inflated_table()
    key = _key_homed_on(table, 0)
    holder, contender = mem.spawn(0), mem.spawn(1)
    lease, st = _inflate_key(mem, table, clock, key, holder, contender)
    shard = table.shards[table.shard_of(key)]
    assert shard.inflations == 1
    # The pre-inflation holder's lease predates the mode flip; its release
    # must still succeed (slow path: fence register is untouched until the
    # first CS grant on the inflated key reserves the token block).
    assert table.release(holder, lease) is True
    etok, readers, eexp = mem.read(holder, st.expires)
    assert _infl(readers), "release must not deflate by accident"


def test_direct_handoff_chain_tokens_and_counters():
    mem, table, clock = _inflated_table()
    key = _key_homed_on(table, 0)
    home = mem.spawn(0)
    holder = mem.spawn(0)
    a, b, c = mem.spawn(1), mem.spawn(1), mem.spawn(1)
    lease, st = _inflate_key(mem, table, clock, key, holder, a)
    shard = table.shards[table.shard_of(key)]
    # First post-inflation attempts route through the queue: a enqueues as
    # cohort leader, b and c park behind it.
    assert table.try_acquire(a, key, ttl=10.0) is None
    assert table.try_acquire(b, key, ttl=10.0) is None
    assert table.try_acquire(c, key, ttl=10.0) is None
    assert table.queued(a, key) and table.queued(b, key)
    table.release(holder, lease)
    # Head takes the word via the CS grant: this reserves the fence block.
    la = None
    for _ in range(5):
        la = table.try_acquire(a, key, ttl=10.0)
        if la is not None:
            break
    assert la is not None and la.inflated
    assert st.infl_ceiling == la.token + _INFL_RESERVE
    assert mem.read(home, st.fence) == st.infl_ceiling
    # Release with a successor parked: direct handoff — one witness CAS,
    # token chained through the word, NO critical section for b's grant.
    handoffs0 = shard.queue_handoffs
    assert table.release(a, la) is True
    assert shard.queue_handoffs == handoffs0 + 1
    lb = table.try_acquire(b, key, ttl=10.0)
    assert lb is not None and lb.inflated
    assert lb.token == la.token + 1          # word-chained allocation
    assert lb.token < st.infl_ceiling        # strictly under the ceiling
    # And the chain continues: b -> c the same way.
    assert table.release(b, lb) is True
    lc = table.try_acquire(c, key, ttl=10.0)
    assert lc is not None and lc.token == lb.token + 1
    assert table.release(c, lc) is True


def test_cooled_key_deflates_and_next_grant_repairs_fence():
    mem, table, clock = _inflated_table()
    key = _key_homed_on(table, 0)
    holder, a = mem.spawn(0), mem.spawn(1)
    lease, st = _inflate_key(mem, table, clock, key, holder, a)
    shard = table.shards[table.shard_of(key)]
    table.release(holder, lease)
    la = None
    for _ in range(5):
        la = table.try_acquire(a, key, ttl=10.0)
        if la is not None:
            break
    assert la is not None and la.inflated
    ceiling = st.infl_ceiling
    # Cool off: two windows of silence, then release with an empty queue.
    clock.advance(5e-3)
    assert table.release(a, la) is True
    assert st.infl is None and shard.deflations == 1
    assert not table.queued(a, key)
    # The deflated word sits under the still-raised fence: untrusted, so
    # the next grant repairs it ABOVE the old epoch's ceiling.
    nxt = table.try_acquire(a, key, ttl=10.0)
    assert nxt is not None and not nxt.inflated
    assert nxt.token == ceiling + 1
    assert shard.repairs >= 1
    assert table.release(a, nxt) is True


def test_uniform_key_never_inflates():
    mem, table, clock = _inflated_table()
    p = mem.spawn(0)
    for i in range(64):
        lease = table.try_acquire(p, f"cold/{i}", ttl=10.0)
        assert lease is not None and not lease.inflated
        assert table.release(p, lease)
    assert all(s.inflations == 0 for s in table.shards)


def test_queued_is_metadata_only():
    mem, table, clock = _inflated_table()
    p = mem.spawn(0)
    assert not table.queued(p, "nope")
    ops0 = p.counts.as_tuple()
    table.queued(p, "nope")
    assert p.counts.as_tuple() == ops0  # zero simulated ops
