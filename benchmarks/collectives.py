"""Benchmark 3 — cohort vs flat gradient exchange (the paper's effect, TPU).

Two sources:
* the asymmetry cost model (`repro.core.asymmetry`) — DCN bytes/chip for the
  flat (naive "everyone crosses the fabric") vs cohort schedule, per arch;
* measured dry-run JSONs when present (results/): DCN wire bytes of the
  multi-pod train cells, which lower the cohort schedule.
"""

import glob
import json
import os

from repro.core.asymmetry import TPUv5e, cohort_vs_flat_dcn_bytes


def run(report):
    hw = TPUv5e()
    for arch, grad_gb in (
        ("llama3-8b", 16.1),          # bf16 grads
        ("deepseek-v3-671b", 1343.0),
    ):
        r = cohort_vs_flat_dcn_bytes(grad_gb * 1e9, pods=2, chips_per_pod=256)
        flat_s = r["flat_dcn_bytes_per_chip"] / hw.dcn_bw_per_chip
        coh_s = r["cohort_dcn_bytes_per_chip"] / hw.dcn_bw_per_chip
        report(
            f"collectives/{arch}_flat_dcn_s", flat_s * 1e6,
            f"model: flat all-reduce spans DCN ({r['flat_dcn_bytes_per_chip'] / 1e9:.2f} GB/chip)",
        )
        report(
            f"collectives/{arch}_cohort_dcn_s", coh_s * 1e6,
            f"model: fragments only ({r['cohort_dcn_bytes_per_chip'] / 1e9:.3f} GB/chip, "
            f"{r['reduction']:.0f}x less)",
        )
    # measured (if the dry-run has been run)
    for path in sorted(glob.glob("results/*train_4k__2x16x16__sync.json")):
        rec = json.load(open(path))
        if "skipped" in rec:
            continue
        dcn = rec["parsed"]["dcn_wire_bytes_per_chip"]
        report(
            f"collectives/measured_dcn_{rec['arch']}",
            dcn / hw.dcn_bw_per_chip * 1e6,
            f"dry-run multi-pod cohort: {dcn / 1e9:.2f} GB/chip over DCN",
        )
