"""Benchmark 4 — real step wall-time on CPU for reduced configs.

Not a TPU measurement (see §Roofline for the target-hardware analysis); this
tracks relative regressions of the end-to-end step across code changes and
exercises the full train/serve paths.
"""

import time

import jax
import jax.numpy as jnp

from repro.compat import set_mesh
from repro.configs import RunConfig, ShapeConfig, get_config
from repro.launch.mesh import make_mesh
from repro.launch.steps import (
    build_decode_step,
    build_prefill_step,
    build_train_step,
    init_train_state,
)
from repro.models import Model, input_specs


def _time(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters, out


def _time_step(step, state, batch, iters=3):
    """Train steps donate the state: thread it through the timing loop."""
    state, m = step(state, batch)
    jax.block_until_ready(m)
    t0 = time.perf_counter()
    for _ in range(iters):
        state, m = step(state, batch)
    jax.block_until_ready(m)
    return (time.perf_counter() - t0) / iters, state


def run(report):
    mesh = make_mesh((1, 1), ("data", "model"))
    for arch in ("llama3.2-1b", "deepseek-v2-236b", "recurrentgemma-9b",
                 "xlstm-1.3b"):
        cfg = get_config(arch, smoke=True)
        model = Model(cfg)
        shape = ShapeConfig("bench", seq_len=128, global_batch=4, kind="train")
        run_cfg = RunConfig(total_steps=10)
        with set_mesh(mesh):
            step, _, state_sh, batch_sh = build_train_step(
                model, run_cfg, mesh, shape
            )
            state = jax.device_put(
                init_train_state(model, run_cfg, jax.random.PRNGKey(0)), state_sh
            )
            batch = jax.device_put(
                input_specs(cfg, shape, concrete=True), batch_sh
            )
            dt, state = _time_step(step, state, batch)
        report(f"step_bench/train_{arch}", dt * 1e6,
               "smoke config, B=4 T=128, CPU")

    # decode step
    cfg = get_config("llama3.2-1b", smoke=True)
    model = Model(cfg)
    with set_mesh(mesh):
        pshape = ShapeConfig("bench", seq_len=32, global_batch=4, kind="prefill")
        prefill, _, (psh, bsh, csh) = build_prefill_step(model, mesh, pshape, 64)
        dshape = ShapeConfig("bench", seq_len=64, global_batch=4, kind="decode")
        decode, _, _ = build_decode_step(model, mesh, dshape, 64)
        params = jax.device_put(model.init(jax.random.PRNGKey(0)), psh)
        batch = jax.device_put(input_specs(cfg, pshape, concrete=True), bsh)
        logits, caches = prefill(params, batch)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        logits, caches = decode(params, caches, tok)  # warmup (caches donated)
        jax.block_until_ready(logits)
        t0 = time.perf_counter()
        iters = 5
        for _ in range(iters):
            logits, caches = decode(params, caches, tok)
        jax.block_until_ready(logits)
        dt = (time.perf_counter() - t0) / iters
    report("step_bench/decode_llama3.2-1b", dt * 1e6, "per-token, CPU")
