"""Benchmark 5 — Pallas kernels (interpret mode): correctness deltas + block
shape sweep. Wall times on CPU interpret mode are NOT TPU estimates; the
derived column carries the VMEM working-set math that sizes the tiles.
"""

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref


def run(report):
    B, T, H, K, d = 1, 256, 4, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, T, H, d), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, K, d), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, K, d), jnp.float32)
    expect = ref.flash_attention_ref(q, k, v, causal=True)
    for qb, kb in ((64, 64), (128, 128), (64, 256)):
        t0 = time.perf_counter()
        out = ops.flash_attention(q, k, v, True, 0, qb, kb, None)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        err = float(jnp.max(jnp.abs(out - expect)))
        vmem_kb = (qb * d + kb * d + qb * kb + qb * d) * 4 / 1024
        report(
            f"kernel_bench/flash_qb{qb}_kb{kb}", dt * 1e6,
            f"err={err:.1e} vmem_working_set={vmem_kb:.0f}KiB "
            f"(v5e VMEM 16MiB)",
        )

    a = jax.nn.sigmoid(jax.random.normal(ks[0], (2, 512, 256))) * 0.6 + 0.3
    b = jax.random.normal(ks[1], (2, 512, 256)) * 0.1
    h0 = jnp.zeros((2, 256))
    expect = ref.rglru_scan_ref(a, b, h0)
    for tb in (128, 256):
        t0 = time.perf_counter()
        from repro.kernels.rglru_scan import rglru_scan_fwd

        out = rglru_scan_fwd(a, b, h0, t_block=tb, w_block=256, interpret=True)
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        err = float(jnp.max(jnp.abs(out - expect)))
        report(f"kernel_bench/rglru_tb{tb}", dt * 1e6,
               f"err={err:.1e} vmem={3 * tb * 256 * 4 / 1024:.0f}KiB")
