"""Benchmark 6 — sharded lock table: throughput scaling and fairness.

Sweeps hosts × shards × contention over the simulated fabric (remote ops carry
the same injected ~20 µs latency as ``lock_compare``) and reports, per config:

* aggregate lease acquisitions/second across all client threads,
* a Jain fairness index over per-client acquisition counts,
* per-class RDMA ops per acquisition from the table's own telemetry —
  verifying the tentpole claim that **home-shard clients issue zero simulated
  RDMA ops** (every host is the paper's local class for its shard slice).

``shards=1`` is the pre-sharding baseline (one ALock service fronting the
whole keyspace, host 0 privileged); larger shard counts spread the privilege
so aggregate throughput scales and fairness across hosts improves.

Workloads:

* ``home``    — each client only touches keys homed on its own host (the
  placement-aware layout a sharded KV store would use);
* ``uniform`` — every client draws keys uniformly (placement-oblivious).
"""

import random
import threading
import time

from repro.core import AsymmetricMemory, OpCounts, make_scheduler
from repro.coord import ShardedLockTable
from repro.coord.table import LOCAL, REMOTE

REMOTE_DELAY = 20e-6  # 20 µs per remote op, paper §1's ~10× asymmetry
KEYS_PER_HOST = 8
TTL = 60.0


class _DelayMem(AsymmetricMemory):
    def rread(self, p, reg):
        time.sleep(REMOTE_DELAY)
        return super().rread(p, reg)

    def rwrite(self, p, reg, value):
        time.sleep(REMOTE_DELAY)
        super().rwrite(p, reg, value)

    def rcas(self, p, reg, expected, swap):
        time.sleep(REMOTE_DELAY)
        return super().rcas(p, reg, expected, swap)


def _jain(xs):
    xs = [x for x in xs if x >= 0]
    total = sum(xs)
    if total == 0:
        return 0.0
    return total * total / (len(xs) * sum(x * x for x in xs))


def _keys_by_home(table, num_hosts):
    """KEYS_PER_HOST keys per host, found by stable-hash placement.

    With fewer shards than hosts (the ``shards=1`` baseline) some hosts own
    no shard at all; they fall back to keys homed elsewhere — which is
    exactly the baseline's cost story: locality is impossible for them.
    """
    per_host = {h: [] for h in range(num_hosts)}
    pool = []
    for i in range(20_000):
        if all(len(v) >= KEYS_PER_HOST for v in per_host.values()):
            break
        k = f"record/{i}"
        pool.append(k)
        h = table.home_of(k)
        if len(per_host[h]) < KEYS_PER_HOST:
            per_host[h].append(k)
    for h in range(num_hosts):
        j = 0
        while len(per_host[h]) < KEYS_PER_HOST:
            per_host[h].append(pool[(h * KEYS_PER_HOST + j) % len(pool)])
            j += 1
    return per_host


def _bench(num_hosts, num_shards, workload, seconds=0.4, seed=0):
    rng = random.Random(seed)
    mem = _DelayMem(num_hosts, sched=make_scheduler(rng, 0.05))
    table = ShardedLockTable(mem, num_shards=num_shards)
    per_host = _keys_by_home(table, num_hosts)
    all_keys = [k for ks in per_host.values() for k in ks]

    counts = []
    stop = threading.Event()

    def client(host, idx):
        p = mem.spawn(host)
        r = random.Random(seed * 1000 + idx)
        keys = per_host[host] if workload == "home" else all_keys
        n = 0
        while not stop.is_set():
            lease = table.try_acquire(p, r.choice(keys), TTL)
            if lease is not None:
                n += 1
                table.release(p, lease)
        counts[idx] = n

    threads = []
    for h in range(num_hosts):
        for _ in range(2):  # two client threads per host
            idx = len(counts)
            counts.append(0)
            threads.append(threading.Thread(target=client, args=(h, idx)))
    for t in threads:
        t.start()
    time.sleep(seconds)
    stop.set()
    for t in threads:
        t.join()

    total = sum(counts)
    totals = table.class_totals()
    grants = max(sum(r["grants"] for r in table.telemetry()), 1)
    return {
        "throughput": total / seconds,
        "jain": _jain(counts),
        "local_rdma": totals[LOCAL].rdma_ops,
        "remote_rdma_per_acq": totals[REMOTE].rdma_ops / grants,
    }


def run(report):
    num_hosts = 4
    for workload in ("home", "uniform"):
        base = None
        for shards in (1, 4, 16):
            r = _bench(num_hosts, shards, workload)
            assert r["local_rdma"] == 0, (
                f"home-shard clients paid RDMA ops: {r['local_rdma']}"
            )
            if shards == 1:
                base = r["throughput"]
            speedup = r["throughput"] / max(base, 1e-9)
            report(
                f"lock_table/{workload}/hosts{num_hosts}/shards{shards}",
                1e6 / max(r["throughput"], 1e-9),  # µs per acquisition
                f"thru={r['throughput']:.0f}/s x{speedup:.2f} "
                f"jain={r['jain']:.3f} "
                f"rRDMA/acq={r['remote_rdma_per_acq']:.2f} localRDMA=0",
            )


def main():
    rows = []

    def report(name, us, derived=""):
        rows.append(name)
        print(f"{name},{us:.3f},{derived}")

    run(report)
    print(f"# {len(rows)} lock-table rows")


if __name__ == "__main__":
    main()
