"""Benchmark 6 — sharded lock table: throughput scaling, fairness, and the
hot-path fast paths (renewals, shard-grouped batches, doorbell coalescing).

Sweeps hosts × shards × workload over the simulated fabric.  Remote *postings*
carry an injected ~20 µs latency: each individually-posted op rings its own
doorbell, while a ``post_batch`` of N work requests rings one — so the delay
model prices doorbells, which is exactly what RDMA WR-list coalescing buys.

Per config the bench reports:

* aggregate lease operations/second across all client threads,
* a Jain fairness index over per-client operation counts,
* per-class RDMA completions and doorbells per operation from the table's own
  telemetry — verifying that **home-shard clients issue zero simulated RDMA
  ops** and that local-holder renewals are RDMA-free (remote holders ≤1 rCAS).

Workloads:

* ``home``    — each client only touches keys homed on its own host (the
  placement-aware layout a sharded KV store would use);
* ``uniform`` — every client draws keys uniformly (placement-oblivious);
* ``renew``   — renewal-heavy: each client holds one lease on a key homed on
  its **own** host and keepalives in a loop (the zero-RDMA fast path);
* ``renew_remote`` — same, but the key is homed on another host (the 1-rCAS
  fast path);
* ``batch``   — batch-heavy: each client loops ``acquire_batch`` /
  ``release_batch`` over its own multi-shard key set (one ALock critical
  section per shard group, reads/writes doorbell-coalesced).

``BASELINE`` records the pre-optimisation numbers (per-key critical sections,
per-op doorbells, ALock-guarded renewals) so ``--json`` emits a before/after
perf trajectory.
"""

import argparse
import json
import random
import threading
import time

from repro.core import AsymmetricMemory, make_scheduler
from repro.coord import ShardedLockTable
from repro.coord.table import LOCAL, REMOTE

REMOTE_DELAY = 20e-6  # 20 µs per remote *posting*, paper §1's ~10× asymmetry
KEYS_PER_HOST = 8
BATCH_KEYS = 8
TTL = 60.0

# Pre-PR numbers (same machine, commit 3e028bd: per-key critical sections,
# one doorbell per op, ALock-guarded renew/release), measured with this
# file's protocol — median throughput over seeds (0, 1, 2) at 0.7 s per run.
# Current runs take the median over SEEDS (more seeds, same estimator: the
# 2-core container occasionally drops a whole run-batch ~40 % low, and the
# wider median shrugs that off).  The renewal and batch workloads did not
# exist then — their baseline is the uniform acquire/release path they
# previously had to ride.
BASELINE = {
    "home/shards1": 218.6,
    "home/shards4": 3341.4,
    "home/shards16": 4544.3,
    "uniform/shards1": 238.6,
    "uniform/shards4": 457.1,
    "uniform/shards16": 788.6,
}
SEEDS = (0, 1, 2, 3, 4)


class _DelayMem(AsymmetricMemory):
    """Inject fabric latency per doorbell: one posting, one ~RTT."""

    def rread(self, p, reg):
        time.sleep(REMOTE_DELAY)
        return super().rread(p, reg)

    def rwrite(self, p, reg, value):
        time.sleep(REMOTE_DELAY)
        super().rwrite(p, reg, value)

    def rcas(self, p, reg, expected, swap):
        time.sleep(REMOTE_DELAY)
        return super().rcas(p, reg, expected, swap)

    def post_batch(self, p, wrs):
        time.sleep(REMOTE_DELAY)  # one doorbell, regardless of len(wrs)
        return super().post_batch(p, wrs)


def _jain(xs):
    xs = [x for x in xs if x >= 0]
    total = sum(xs)
    if total == 0:
        return 0.0
    return total * total / (len(xs) * sum(x * x for x in xs))


def _keys_by_home(table, num_hosts):
    """KEYS_PER_HOST keys per host, found by stable-hash placement.

    With fewer shards than hosts (the ``shards=1`` baseline) some hosts own
    no shard at all; they fall back to keys homed elsewhere — which is
    exactly the baseline's cost story: locality is impossible for them.
    """
    per_host = {h: [] for h in range(num_hosts)}
    pool = []
    for i in range(20_000):
        if all(len(v) >= KEYS_PER_HOST for v in per_host.values()):
            break
        k = f"record/{i}"
        pool.append(k)
        h = table.home_of(k)
        if len(per_host[h]) < KEYS_PER_HOST:
            per_host[h].append(k)
    for h in range(num_hosts):
        j = 0
        while len(per_host[h]) < KEYS_PER_HOST:
            per_host[h].append(pool[(h * KEYS_PER_HOST + j) % len(pool)])
            j += 1
    return per_host


def _key_homed_on(table, host, salt):
    for i in range(50_000):
        k = f"lease/{salt}/{i}"
        if table.home_of(k) == host:
            return k
    return f"lease/{salt}/0"  # shards < hosts: host owns nothing; any key


def _bench(num_hosts, num_shards, workload, seconds=0.4, seed=0):
    rng = random.Random(seed)
    mem = _DelayMem(num_hosts, sched=make_scheduler(rng, 0.05))
    table = ShardedLockTable(mem, num_shards=num_shards)
    per_host = _keys_by_home(table, num_hosts)
    all_keys = [k for ks in per_host.values() for k in ks]

    counts = []
    procs = []
    stop = threading.Event()

    def acq_client(host, idx):
        p = procs[idx]
        r = random.Random(seed * 1000 + idx)
        keys = per_host[host] if workload == "home" else all_keys
        n = 0
        while not stop.is_set():
            lease = table.try_acquire(p, r.choice(keys), TTL)
            if lease is not None:
                n += 1
                table.release(p, lease)
        counts[idx] = n

    renew_keys = {}  # resolved before the clock starts: hashing 50k
    # candidate keys per client inside the timed window would understate
    # the shards=1 rows and skew the recorded speedups.

    def renew_client(host, idx):
        p = procs[idx]
        lease = table.acquire(p, renew_keys[idx], TTL, timeout=30.0)
        n = 0
        while not stop.is_set():
            lease = table.renew(p, lease)
            assert lease is not None, "holder lost its own live lease"
            n += 1
        counts[idx] = n

    def batch_client(host, idx):
        p = procs[idx]
        keys = [f"batch/h{host}/c{idx}/k{i}" for i in range(BATCH_KEYS)]
        n = 0
        while not stop.is_set():
            leases = table.acquire_batch(p, keys, TTL, timeout=30.0)
            n += len(leases)
            table.release_batch(p, leases)
        counts[idx] = n

    target = {"home": acq_client, "uniform": acq_client,
              "renew": renew_client, "renew_remote": renew_client,
              "batch": batch_client}[workload]
    threads = []
    for h in range(num_hosts):
        for _ in range(2):  # two client threads per host
            idx = len(counts)
            counts.append(0)
            procs.append(mem.spawn(h))
            if workload in ("renew", "renew_remote"):
                t = h if workload == "renew" else (h + 1) % num_hosts
                renew_keys[idx] = _key_homed_on(table, t, salt=f"h{h}c{idx}")
            threads.append(threading.Thread(target=target, args=(h, idx)))
    for t in threads:
        t.start()
    time.sleep(seconds)
    stop.set()
    for t in threads:
        t.join()

    total = sum(counts)
    totals = table.class_totals()
    rows = table.telemetry()
    grants = max(sum(r["grants"] for r in rows), 1)
    ops = max(total, 1)  # acquisitions or renewals, per workload
    assert totals[LOCAL].rdma_ops == 0, (
        f"{workload}: local-class clients paid RDMA ops: "
        f"{totals[LOCAL].rdma_ops}"
    )
    if workload == "renew":
        # Renewal-heavy with same-host keys: every renewal must ride the
        # zero-RDMA local fast path (no shard ALock, no fabric).
        assert sum(r["fast_renews"] for r in rows) >= total
    if workload == "renew_remote" and num_shards >= num_hosts:
        # Remote holders: exactly one rCAS per fast-path renewal (plus the
        # bounded one-time acquire cost per client thread).
        assert totals[REMOTE].remote_cas <= total + 16 * 2 * num_hosts
    return {
        "workload": workload,
        "shards": num_shards,
        "throughput": total / seconds,
        "jain": _jain(counts),
        "local_rdma": totals[LOCAL].rdma_ops,
        "remote_rdma_per_op": totals[REMOTE].rdma_ops / ops,
        "remote_doorbells_per_op": totals[REMOTE].remote_doorbell / ops,
        "remote_cas": totals[REMOTE].remote_cas,
        "fast_renews": sum(r["fast_renews"] for r in rows),
        "fast_releases": sum(r["fast_releases"] for r in rows),
        "grants": grants,
        "total_ops": total,
    }


def _bench_median(num_hosts, shards, workload, seconds, seeds=SEEDS):
    """Median-throughput run over ``seeds``.

    Thread scheduling on an oversubscribed box makes single short runs noisy
    (±30 % run-to-run); the median over a few seeds is what BASELINE was
    recorded with and what the JSON trajectory stores.
    """
    import gc
    runs = []
    for s in seeds:
        gc.collect()  # don't let a prior config's garbage pause this run
        runs.append(_bench(num_hosts, shards, workload, seconds=seconds, seed=s))
    runs.sort(key=lambda r: r["throughput"])
    med = dict(runs[len(runs) // 2])
    med["throughput_runs"] = [round(r["throughput"], 1) for r in runs]
    return med


BENCH_NAME = "lock_table"
_LAST = {"results": [], "seconds": None}  # for benchmarks.run --json


def json_extra():
    """Hook for ``benchmarks.run --json``: the before/after trajectory."""
    return json_payload(_LAST["results"], _LAST["seconds"])


def run(report, seconds=0.7, seeds=SEEDS):
    _LAST["results"] = results = []
    _LAST["seconds"] = seconds
    num_hosts = 4
    for workload in ("home", "uniform", "renew", "renew_remote", "batch"):
        base = None
        for shards in (1, 4, 16):
            r = _bench_median(num_hosts, shards, workload, seconds, seeds)
            if shards == 1:
                base = r["throughput"]
            r["speedup_vs_1shard"] = r["throughput"] / max(base, 1e-9)
            results.append(r)
            report(
                f"lock_table/{workload}/hosts{num_hosts}/shards{shards}",
                1e6 / max(r["throughput"], 1e-9),  # µs per operation
                f"thru={r['throughput']:.0f}/s x{r['speedup_vs_1shard']:.2f} "
                f"jain={r['jain']:.3f} "
                f"rRDMA/op={r['remote_rdma_per_op']:.2f} "
                f"doorbells/op={r['remote_doorbells_per_op']:.2f} "
                f"fastrenew={r['fast_renews']} localRDMA=0",
            )


def json_payload(results, seconds):
    """The machine-readable perf-trajectory record (BENCH_lock_table.json)."""
    current = {}
    for r in results:
        current[f"{r['workload']}/shards{r['shards']}"] = {
            k: v for k, v in r.items() if k not in ("workload", "shards")
        }
    speedups = {
        cfg: round(current[cfg]["throughput"] / before, 3)
        for cfg, before in BASELINE.items()
        if cfg in current and before > 0
    }
    return {
        "bench": "lock_table",
        "config": {
            "hosts": 4,
            "clients_per_host": 2,
            "seconds": seconds,
            "keys_per_host": KEYS_PER_HOST,
            "batch_keys": BATCH_KEYS,
            "remote_delay_us": REMOTE_DELAY * 1e6,
        },
        "baseline_pre_pr": BASELINE,
        "current": current,
        "speedup_vs_baseline": speedups,
    }


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config for CI (short runs, same assertions)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the before/after results to PATH")
    args = ap.parse_args()
    seconds = 0.1 if args.smoke else 0.7
    seeds = (0,) if args.smoke else SEEDS

    rows = []

    def report(name, us, derived=""):
        rows.append(name)
        print(f"{name},{us:.3f},{derived}")

    run(report, seconds=seconds, seeds=seeds)
    print(f"# {len(rows)} lock-table rows")
    if args.json:
        payload = json_extra()
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}")
        for cfg, x in sorted(payload["speedup_vs_baseline"].items()):
            print(f"#   {cfg}: {x:.2f}x vs pre-PR")


if __name__ == "__main__":
    main()
