"""Benchmark 6 — sharded lock table: throughput scaling, fairness, and the
hot-path fast paths (renewals, shard-grouped batches, doorbell coalescing),
in two modes:

* **threaded** (the original): clients are OS threads over wall-clock time;
  remote *postings* carry an injected ~20 µs ``time.sleep`` latency.  Numbers
  are medians over seeds because thread scheduling makes short runs noisy —
  the JSON now records that dispersion (CV + IQR over the per-seed runs) so
  the noise itself is measured.
* **sim** (``repro.sim``): clients are cooperative tasks on the deterministic
  virtual-time engine.  Same table, same cost model priced as virtual-clock
  charges — but 64 hosts × 16 clients × 10⁵ lease ops complete in seconds of
  wall time, per-class RDMA/doorbell counters are **exact** (not sampled),
  and a given seed reproduces them byte-for-byte (CI diffs two runs).  This
  unlocks workloads that are infeasible under thread-per-client: a zipfian
  hot-key sweep and a failover storm (mass lease expiry + zombie fencing).

Per config the bench reports aggregate lease ops/second (wall-clock thru in
threaded mode, virtual-time thru in sim mode), a Jain fairness index over
per-client op counts, and per-class RDMA completions/doorbells per op from
the table's own telemetry — verifying that **home-shard clients issue zero
simulated RDMA ops** in both modes and at both scales.

Threaded workloads: ``home``, ``uniform``, ``read_heavy`` (95:5
shared:exclusive mode mix), ``renew``, ``renew_remote``, ``batch`` (see each
client fn).  Sim workloads: ``home``, ``uniform``, ``zipfian``,
``failover``, ``read_heavy``, ``reader_flood``, ``crash_restart``,
``home_death``, ``partition``, ``overload_storm``, ``pipelined_read``
(see ``repro.sim.workloads``), plus the read:write ratio sweep
(``run_rw_sweep``) comparing SHARED readers and seqlock optimistic
readers against an exclusive-only degradation of the same seeded run —
the mode-aware before/after in ``BENCH_lock_table.json`` — the pipeline
sweep (``run_pipeline_sweep``) gating doorbells-per-op under the async
client's coalescing vs a flush_ops=1 control, and the offered-load sweep
(``run_overload_sweep``) gating goodput retention and bounded deadline
overshoot under a 1x->10x storm, shedding ON vs OFF.

``BASELINE`` records the pre-optimisation numbers (per-key critical sections,
per-op doorbells, ALock-guarded renewals) so ``--json`` emits a before/after
perf trajectory.
"""

import argparse
import json
import os
import random
import sys
import threading
import time

from repro.core import AsymmetricMemory, make_scheduler
from repro.coord import (InflationPolicy, LeaseMode, OverloadPolicy,
                         ShardedLockTable)
from repro.coord.table import LOCAL, REMOTE
from repro.sim import SIM_WORKLOADS, run_lock_table_sim
from repro.sim.workloads import KEYS_PER_HOST, jain as _jain, keys_by_home

REMOTE_DELAY = 20e-6  # 20 µs per remote *posting*, paper §1's ~10× asymmetry
BATCH_KEYS = 8
TTL = 60.0

# Pre-PR numbers (same machine, commit 3e028bd: per-key critical sections,
# one doorbell per op, ALock-guarded renew/release), measured with this
# file's protocol — median throughput over seeds (0, 1, 2) at 0.7 s per run.
# Current runs take the median over SEEDS (more seeds, same estimator: the
# 2-core container occasionally drops a whole run-batch ~40 % low, and the
# wider median shrugs that off).  The renewal and batch workloads did not
# exist then — their baseline is the uniform acquire/release path they
# previously had to ride.
BASELINE = {
    "home/shards1": 218.6,
    "home/shards4": 3341.4,
    "home/shards16": 4544.3,
    "uniform/shards1": 238.6,
    "uniform/shards4": 457.1,
    "uniform/shards16": 788.6,
}
SEEDS = (0, 1, 2, 3, 4)
BASELINE_CPU_COUNT = 2   # the box BASELINE (and the recorded JSON) came from
CV_WARN = 0.25           # seed-to-seed throughput CV past this is noise, not
                         # signal — the runner warns rather than recording it
                         # silently (the shards1 rows on a loaded 2-core box
                         # are the usual offenders)


class _DelayMem(AsymmetricMemory):
    """Inject fabric latency per doorbell: one posting, one ~RTT."""

    def rread(self, p, reg):
        time.sleep(REMOTE_DELAY)
        return super().rread(p, reg)

    def rwrite(self, p, reg, value):
        time.sleep(REMOTE_DELAY)
        super().rwrite(p, reg, value)

    def rcas(self, p, reg, expected, swap):
        time.sleep(REMOTE_DELAY)
        return super().rcas(p, reg, expected, swap)

    def post_batch(self, p, wrs):
        time.sleep(REMOTE_DELAY)  # one doorbell, regardless of len(wrs)
        return super().post_batch(p, wrs)


def _keys_by_home(table, num_hosts):
    """KEYS_PER_HOST keys per host via the shared placement scanner.

    Non-strict: with fewer shards than hosts (the ``shards=1`` baseline)
    some hosts own no shard at all and fall back to keys homed elsewhere —
    which is exactly the baseline's cost story: locality is impossible for
    them.  ``prefix="record/"`` keeps the key universe (and so the shard
    placement) identical to the runs BASELINE was recorded with.
    """
    return keys_by_home(table, num_hosts, KEYS_PER_HOST,
                        prefix="record/", strict=False)


def _key_homed_on(table, host, salt):
    for i in range(50_000):
        k = f"lease/{salt}/{i}"
        if table.home_of(k) == host:
            return k
    return f"lease/{salt}/0"  # shards < hosts: host owns nothing; any key


def _bench(num_hosts, num_shards, workload, seconds=0.4, seed=0):
    rng = random.Random(seed)
    mem = _DelayMem(num_hosts, sched=make_scheduler(rng, 0.05))
    table = ShardedLockTable(mem, num_shards=num_shards)
    per_host = _keys_by_home(table, num_hosts)
    all_keys = [k for ks in per_host.values() for k in ks]

    counts = []
    procs = []
    stop = threading.Event()

    def acq_client(host, idx):
        p = procs[idx]
        r = random.Random(seed * 1000 + idx)
        keys = per_host[host] if workload == "home" else all_keys
        n = 0
        while not stop.is_set():
            lease = table.try_acquire(p, r.choice(keys), TTL)
            if lease is not None:
                n += 1
                table.release(p, lease)
        counts[idx] = n

    def read_heavy_client(host, idx):
        # The mode-aware mix: 95 % shared joins (single CAS, no shard
        # ALock), 5 % exclusive writer grants, same key universe as
        # ``uniform`` so the rows are comparable.
        p = procs[idx]
        r = random.Random(seed * 1000 + idx)
        n = 0
        while not stop.is_set():
            mode = (LeaseMode.EXCLUSIVE if r.random() < 0.05
                    else LeaseMode.SHARED)
            lease = table.try_acquire(p, r.choice(all_keys), TTL, mode=mode)
            if lease is not None:
                n += 1
                table.release(p, lease)
        counts[idx] = n

    renew_keys = {}  # resolved before the clock starts: hashing 50k
    # candidate keys per client inside the timed window would understate
    # the shards=1 rows and skew the recorded speedups.

    def renew_client(host, idx):
        p = procs[idx]
        lease = table.acquire(p, renew_keys[idx], TTL, timeout=30.0)
        n = 0
        while not stop.is_set():
            lease = table.renew(p, lease)
            assert lease is not None, "holder lost its own live lease"
            n += 1
        counts[idx] = n

    def batch_client(host, idx):
        p = procs[idx]
        keys = [f"batch/h{host}/c{idx}/k{i}" for i in range(BATCH_KEYS)]
        n = 0
        while not stop.is_set():
            leases = table.acquire_batch(p, keys, TTL, timeout=30.0)
            n += len(leases)
            table.release_batch(p, leases)
        counts[idx] = n

    target = {"home": acq_client, "uniform": acq_client,
              "read_heavy": read_heavy_client,
              "renew": renew_client, "renew_remote": renew_client,
              "batch": batch_client}[workload]
    threads = []
    for h in range(num_hosts):
        for _ in range(2):  # two client threads per host
            idx = len(counts)
            counts.append(0)
            procs.append(mem.spawn(h))
            if workload in ("renew", "renew_remote"):
                t = h if workload == "renew" else (h + 1) % num_hosts
                renew_keys[idx] = _key_homed_on(table, t, salt=f"h{h}c{idx}")
            threads.append(threading.Thread(target=target, args=(h, idx)))
    for t in threads:
        t.start()
    time.sleep(seconds)
    stop.set()
    for t in threads:
        t.join()

    total = sum(counts)
    totals = table.class_totals()
    rows = table.telemetry()
    grants = max(sum(r["grants"] for r in rows), 1)
    ops = max(total, 1)  # acquisitions or renewals, per workload
    assert totals[LOCAL].rdma_ops == 0, (
        f"{workload}: local-class clients paid RDMA ops: "
        f"{totals[LOCAL].rdma_ops}"
    )
    if workload == "renew":
        # Renewal-heavy with same-host keys: every renewal must ride the
        # zero-RDMA local fast path (no shard ALock, no fabric).
        assert sum(r["fast_renews"] for r in rows) >= total
    if workload == "renew_remote" and num_shards >= num_hosts:
        # Remote holders: exactly one rCAS per fast-path renewal (plus the
        # bounded one-time acquire cost per client thread).
        assert totals[REMOTE].remote_cas <= total + 16 * 2 * num_hosts
    return {
        "workload": workload,
        "shards": num_shards,
        # Threaded throughput scales with available cores; record the box
        # so a row is never compared against a baseline from another shape.
        "cpu_count": os.cpu_count(),
        "throughput": total / seconds,
        "jain": _jain(counts),
        "local_rdma": totals[LOCAL].rdma_ops,
        "remote_rdma_per_op": totals[REMOTE].rdma_ops / ops,
        "remote_doorbells_per_op": totals[REMOTE].remote_doorbell / ops,
        "remote_cas": totals[REMOTE].remote_cas,
        "fast_renews": sum(r["fast_renews"] for r in rows),
        "fast_releases": sum(r["fast_releases"] for r in rows),
        "grants_shared": sum(r["grants_shared"] for r in rows),
        "grants_exclusive": sum(r["grants_exclusive"] for r in rows),
        "shared_joins": sum(r["shared_joins"] for r in rows),
        "intent_blocks": sum(r["intent_blocks"] for r in rows),
        "grants": grants,
        "total_ops": total,
    }


def _bench_median(num_hosts, shards, workload, seconds, seeds=SEEDS):
    """Median-throughput run over ``seeds``.

    Thread scheduling on an oversubscribed box makes single short runs noisy
    (±30 % run-to-run); the median over a few seeds is what BASELINE was
    recorded with and what the JSON trajectory stores.
    """
    import gc
    runs = []
    for s in seeds:
        gc.collect()  # don't let a prior config's garbage pause this run
        runs.append(_bench(num_hosts, shards, workload, seconds=seconds, seed=s))
    runs.sort(key=lambda r: r["throughput"])
    med = dict(runs[len(runs) // 2])
    thr = [round(r["throughput"], 1) for r in runs]
    med["throughput_runs"] = thr
    # Dispersion alongside the median: the run-to-run noise is part of the
    # result (and the thing sim mode eliminates), so measure it — CV over
    # the seed runs plus the IQR (both 0.0 for single-seed smoke runs).
    n = len(thr)
    mean = sum(thr) / n
    if n >= 2 and mean > 0:
        sd = (sum((x - mean) ** 2 for x in thr) / (n - 1)) ** 0.5
        med["throughput_cv"] = round(sd / mean, 4)
        med["throughput_iqr"] = round(thr[(3 * (n - 1)) // 4] - thr[(n - 1) // 4], 1)
    else:
        med["throughput_cv"] = 0.0
        med["throughput_iqr"] = 0.0
    return med


BENCH_NAME = "lock_table"
_LAST = {"results": [], "seconds": None, "sim": None}  # for benchmarks.run --json

# Sim-mode sweep: the scale the threaded bench cannot reach (its practical
# ceiling is 4 hosts × 2 threads).  The zipfian config is the acceptance
# sweep — 64×16 sticky hot-key clients with lock inflation ON — and runs
# at full size even under --smoke; the other workloads shrink their op
# targets there.
SIM_HOSTS, SIM_CPH, SIM_SHARDS = 64, 16, 128
SIM_OPS = {"home": 50_000, "uniform": 50_000,
           "zipfian": 20_000, "failover": 25_000,
           "read_heavy": 50_000, "reader_flood": 20_000,
           "crash_restart": 20_000, "home_death": 20_000,
           "partition": 10_000, "overload_storm": 20_000,
           "pipelined_read": 20_000}
SIM_SMOKE_OPS = {"home": 25_000, "uniform": 25_000,
                 "zipfian": 20_000, "failover": 10_000,
                 "read_heavy": 25_000, "reader_flood": 10_000,
                 "crash_restart": 8_000, "home_death": 8_000,
                 "partition": 5_000, "overload_storm": 8_000,
                 "pipelined_read": 8_000}
# The zipfian rows park hundreds of sticky clients on a handful of keys;
# their event budget is queue/backoff polling, not ops, so the default
# per-op event cap is far too tight for them.
ZIPF_MAX_EVENTS = 120_000_000

# Recovery sweep (sim): the crash-recovery acceptance numbers, at a scale
# (128 hosts) only the virtual-time engine reaches.  Host-level crashes on a
# seeded schedule; the same seeded run twice — once with ledger reclaim, once
# amnesiac — so the wedge/reclaim contrast is a like-for-like protocol delta.
# TTL 1 ms with renewals mid-hold keeps leases in flight at the crash
# instants; restart_delay = TTL/8 models a fast supervisor respawn.  The
# acceptance gate: p99 lease-reclaim latency <= 0.1x TTL, while the amnesiac
# baseline's re-entry latency sits near (or past) the full TTL wedge.
REC_TTL = 1e-3
REC_CFG = dict(num_hosts=128, clients_per_host=4, num_shards=256,
               failover_ttl=REC_TTL, hot_keys=192, crash_hosts=32,
               restart_delay=REC_TTL / 8, crash_warmup=2e-3,
               crash_spacing=REC_TTL / 8)
REC_OPS = 20_000
REC_SMOKE_OPS = 8_000

# Read:write ratio sweep (sim): the mode-aware acceptance numbers.  A hot
# read-mostly working set — one home key per host shared by its 16 clients,
# Zipf(1.2) for the remote tail, 150 µs lease holds — run once with SHARED
# readers and once degraded to exclusive-only, same seed, so the speedup is
# a like-for-like protocol delta (and deterministic).  The 95:5 row is the
# acceptance gate: shared-mode throughput ≥ 3× exclusive-only, home-class
# readers at exactly 0 RDMA ops, remote shared acquires at ≤ 1 rCAS each.
RW_CFG = dict(num_hosts=16, clients_per_host=16, num_shards=32,
              keys_per_host=1, zipf_s=1.2, home_frac=0.9, hold=150e-6)
RW_OPS = 10_000
RW_RATIOS = (0.5, 0.9, 0.95, 0.99)       # read fraction per ratio row
RW_SMOKE_RATIOS = (0.95,)                # CI keeps just the acceptance row
RW_OPT_GATE = 3.49                       # optimistic 95:5 floor = the old
                                         # shared-mode ceiling: seqlock reads
                                         # must beat the best lease path


# Pipelined-read sweep (sim): the doorbell-coalescing acceptance numbers.
# The SAME seeded 64x16 pipelined_read run at flush_ops=1 (every op posts
# its own doorbell the moment it is enqueued — the unpipelined control)
# and at the default flush_ops=8, so the doorbells-per-op delta is a
# like-for-like transport comparison over identical op streams.  Gates:
# the coalesced leg lands under PIPE_DPO_GATE doorbells per completed op
# and strictly improves on the control's doorbell bill.
PIPE_OPS = 20_000
PIPE_SMOKE_OPS = 8_000
PIPE_DPO_GATE = 1.0          # aggregate doorbells-per-op ceiling, coalesced


# Failover sweep (sim): the self-healing acceptance numbers, at the same
# 128-host scale as the recovery sweep.  Two legs, both on the faulty
# fabric: ``home_death`` kills one whole host (picked by the workload's
# seeded schedule) and measures how long the membership detector takes to
# reach a DEAD verdict and how long the epoch-fenced takeover takes to
# re-home the dead shards; ``partition`` cuts a 25 % minority island off
# the fabric for four membership TTLs and checks that the quorum guard
# starves the island (zero in-window grants, refused takeovers) while the
# majority keeps serving.  The membership TTL follows the workload's own
# derivation (one monitor sweep's probe charges must fit inside a sweep
# period), so the gate scales with host count rather than hardcoding a
# latency.  Acceptance: detection p99 AND takeover p99 each within
# FO_GATE_TTLS membership TTLs, every dead-home shard re-homed, zero
# fencing-token regressions (the workload itself raises on any), zero
# minority-side grants.
FO_TTL = REC_TTL
FO_CFG = dict(num_hosts=128, clients_per_host=4, num_shards=256,
              failover_ttl=FO_TTL)
FO_MEMBER_TTL = max(10 * FO_TTL, FO_CFG["num_hosts"] * 100e-6)
FO_GATE_TTLS = 5                 # p99 ceiling, in membership TTLs
FO_OPS = 20_000
FO_SMOKE_OPS = 8_000


# Inflation sweep (sim): the contention-adaptive lock-inflation acceptance
# numbers.  The SAME seeded zipfian run twice — once on the bare CAS word,
# once with the default InflationPolicy — so the delta is a like-for-like
# protocol comparison.  Gates: the hottest key's p99 acquire latency
# improves >= 2x, its per-remote-acquire rCAS drops to a bounded constant
# (direct handoff: one witness CAS + one budget write per grant, plus the
# amortised enqueue), and a uniform workload is unchanged within noise
# (zero inflations: the policy costs one attribute check when cold).
INFL_OPS = 20_000
INFL_P99_GATE = 2.0          # off/on hot-key p99 ratio floor
INFL_RCAS_CAP = 16           # max rCAS any single hot acquire may pay
INFL_UNIFORM_TOL = 0.02      # uniform throughput delta tolerance (2 %)


# Overload sweep (sim): the overload-safe client stack's acceptance
# numbers at the full 64x16 scale.  ``overload_storm`` offers an OPEN-LOOP
# paced arrival stream into a congested fabric; the sweep raises offered
# load 1x -> 10x with the full overload stack ON (deadline propagation +
# feasibility shedding + per-host retry budgets/breakers), then re-runs
# the 10x point with the stack OFF (priority floor, no OverloadPolicy) as
# the retry-storm control.  Gates:
#   * goodput retention — shedding-ON goodput at 10x must hold at least
#     OV_RETENTION of the 1x goodput (overload degrades throughput
#     gracefully instead of collapsing it);
#   * the OFF control must land BELOW the ON leg at 10x (the stack has to
#     beat doing nothing, or it is pure overhead);
#   * non-shed acquire p99 on every ON leg stays within OV_P99_BUDGETS
#     deadline budgets — the deadline machinery's bounded-overshoot
#     guarantee: a grant can return late by at most the one attempt that
#     was already in flight when the deadline passed (a posted CAS cannot
#     be unposted), never by an unbounded retry tail;
#   * 1x must be comfortably served (goodput >= OV_BASE_SERVE of offered)
#     or the "retention" gate would be measuring an already-sick baseline.
OV_TTL = 60e-6               # the storm's contention quantum (see workload)
OV_BUDGET = 10 * OV_TTL      # per-transaction deadline budget
OV_CFG = dict(num_hosts=SIM_HOSTS, clients_per_host=SIM_CPH,
              num_shards=SIM_SHARDS, deadline_budget=OV_BUDGET)
OV_OPS = 20_000
OV_SMOKE_OPS = 8_000
OV_LOADS = (1.0, 3.0, 10.0)  # ON legs; the OFF control runs at the peak
OV_SMOKE_LOADS = (1.0, 10.0)
OV_RETENTION = 0.7           # 10x ON goodput floor, as a fraction of 1x
OV_BASE_SERVE = 0.95         # 1x goodput floor, as a fraction of offered
OV_P99_BUDGETS = 1.5         # ON-leg p99 ceiling, in deadline budgets


def run_inflation_sweep(report, sim_seed=0, smoke=False):
    """Hot-key inflation before/after: the CAS word vs the per-key queue.

    Returns ``(out, on_run)`` — the ON leg is the same configuration as
    ``run_sim``'s zipfian row, so the caller reuses it there instead of
    paying the densest simulation twice.
    """
    out = {"config": dict(num_hosts=SIM_HOSTS, clients_per_host=SIM_CPH,
                          num_shards=SIM_SHARDS, total_ops=INFL_OPS,
                          policy="default")}
    runs = {}
    for label, pol in (("off", None), ("on", InflationPolicy())):
        r = run_lock_table_sim(
            "zipfian", num_hosts=SIM_HOSTS, clients_per_host=SIM_CPH,
            num_shards=SIM_SHARDS, total_ops=INFL_OPS, seed=sim_seed,
            inflation=pol, max_events=ZIPF_MAX_EVENTS)
        runs[label] = r
        out[label] = {
            "virtual_throughput": r.virtual_throughput,
            "ops": r.ops,
            "hot_acquire_p50_us": round(r.hot_acquire_p50 * 1e6, 3),
            "hot_acquire_p99_us": round(r.hot_acquire_p99 * 1e6, 3),
            "hot_acquire_max_us": round(r.hot_acquire_max * 1e6, 3),
            "hot_rcas_mean": round(r.hot_rcas_mean, 3),
            "hot_rcas_max": r.hot_rcas_max,
            "hot_grants": r.hot_grants,
            "inflations": r.inflations,
            "deflations": r.deflations,
            "queue_enqueues": r.queue_enqueues,
            "queue_grants": r.queue_grants,
            "queue_handoffs": r.queue_handoffs,
            "queue_bypasses": r.queue_bypasses,
            "inflation_events": r.inflation_events,
            "hot_key_report": r.hot_key_report,
        }
        report(
            f"lock_table/sim/inflation-{label}/hosts{SIM_HOSTS}x{SIM_CPH}",
            1e6 / max(r.virtual_throughput, 1e-9),
            f"vthru={r.virtual_throughput:.0f}/s "
            f"hot_p99={r.hot_acquire_p99 * 1e6:.0f}us "
            f"hot_rcas_max={r.hot_rcas_max} "
            f"infl={r.inflations} defl={r.deflations} "
            f"handoffs={r.queue_handoffs} wall={r.wall_seconds:.1f}s",
        )
    off, on = runs["off"], runs["on"]
    p99_ratio = off.hot_acquire_p99 / max(on.hot_acquire_p99, 1e-12)
    out["hot_p99_speedup"] = round(p99_ratio, 3)
    out["throughput_ratio"] = round(
        on.virtual_throughput / max(off.virtual_throughput, 1e-9), 3)
    if not on.inflations:
        raise AssertionError(
            "inflation sweep: the zipfian hot keys never inflated — the "
            "policy thresholds no longer match the workload's heat")
    if p99_ratio < INFL_P99_GATE:
        raise AssertionError(
            f"inflation sweep: hot-key p99 improved only {p99_ratio:.2f}x "
            f"(gate {INFL_P99_GATE}x): "
            f"off={off.hot_acquire_p99 * 1e6:.0f}us "
            f"on={on.hot_acquire_p99 * 1e6:.0f}us")
    if on.hot_rcas_max > INFL_RCAS_CAP:
        raise AssertionError(
            f"inflation sweep: a hot acquire paid {on.hot_rcas_max} rCAS "
            f"(cap {INFL_RCAS_CAP}) — the queue is not bounding remote ops")
    # Uniform traffic must not pay for the hot path's machinery.
    uni = {}
    for label, pol in (("off", None), ("on", InflationPolicy())):
        u = run_lock_table_sim(
            "uniform", num_hosts=SIM_HOSTS, clients_per_host=SIM_CPH,
            num_shards=SIM_SHARDS, total_ops=INFL_OPS, seed=sim_seed,
            inflation=pol)
        uni[label] = u
        out[f"uniform_{label}"] = {
            "virtual_throughput": u.virtual_throughput,
            "inflations": u.inflations,
        }
    delta = abs(uni["on"].virtual_throughput - uni["off"].virtual_throughput)
    rel = delta / max(uni["off"].virtual_throughput, 1e-9)
    out["uniform_throughput_delta"] = round(rel, 6)
    if uni["on"].inflations:
        raise AssertionError(
            f"inflation sweep: uniform traffic inflated "
            f"{uni['on'].inflations} keys — thresholds far too hot")
    if rel > INFL_UNIFORM_TOL:
        raise AssertionError(
            f"inflation sweep: uniform throughput moved {rel * 100:.2f}% "
            f"with inflation enabled (tolerance {INFL_UNIFORM_TOL * 100}%)")
    return out, on


def run_rw_sweep(report, sim_seed=0, smoke=False):
    """Shared vs exclusive-only vs optimistic across read:write ratios."""
    sweep = {}
    # The exclusive-only degradation ignores the S/X draw (every op is
    # EXCLUSIVE either way), so one baseline run serves every ratio.
    excl = run_lock_table_sim(
        "read_heavy", total_ops=RW_OPS, seed=sim_seed, shared_reads=False,
        **RW_CFG)
    for read_frac in (RW_SMOKE_RATIOS if smoke else RW_RATIOS):
        wf = round(1.0 - read_frac, 6)
        shared = run_lock_table_sim(
            "read_heavy", total_ops=RW_OPS, seed=sim_seed, write_frac=wf,
            **RW_CFG)
        # Third leg, same seed: readers go through the seqlock
        # (read_optimistic) instead of joining a SHARED lease; writers
        # publish so every snapshot is checkable.  Like-for-like against
        # both lease paths.
        opt = run_lock_table_sim(
            "read_heavy", total_ops=RW_OPS, seed=sim_seed, write_frac=wf,
            read_path="optimistic", **RW_CFG)
        label = f"{round(read_frac * 100)}:{round(wf * 100)}"
        speedup = shared.virtual_throughput / max(excl.virtual_throughput,
                                                  1e-9)
        opt_speedup = opt.virtual_throughput / max(excl.virtual_throughput,
                                                   1e-9)
        rcas_per_join = (shared.shared_acquire_rcas
                         / max(shared.shared_remote_grants, 1))
        sweep[label] = {
            "write_frac": wf,
            "shared": {
                "virtual_throughput": shared.virtual_throughput,
                "ops": shared.ops,
                "grants_shared": shared.grants_shared,
                "grants_exclusive": shared.grants_exclusive,
                "rejects": shared.rejects,
                "intent_blocks": shared.intent_blocks,
                "shared_remote_grants": shared.shared_remote_grants,
                "shared_acquire_rcas": shared.shared_acquire_rcas,
                "local_rdma": sum(
                    v for k, v in shared.cost["local"].items()
                    if k.startswith("remote_") and k != "remote_doorbell"),
            },
            "exclusive_only": {
                "virtual_throughput": excl.virtual_throughput,
                "ops": excl.ops,
                "rejects": excl.rejects,
            },
            "optimistic": {
                "virtual_throughput": opt.virtual_throughput,
                "ops": opt.ops,
                "opt_reads": opt.opt_reads,
                "opt_read_retries": opt.opt_read_retries,
                "opt_read_fallbacks": opt.opt_read_fallbacks,
                "publishes": opt.publishes,
                "expirations": opt.expirations,
                "local_rdma": sum(
                    v for k, v in opt.cost["local"].items()
                    if k.startswith("remote_") and k != "remote_doorbell"),
            },
            "shared_speedup": round(speedup, 3),
            "optimistic_speedup": round(opt_speedup, 3),
            "rcas_per_remote_shared_acquire": round(rcas_per_join, 4),
        }
        report(
            f"lock_table/sim/rw{label}/hosts{RW_CFG['num_hosts']}"
            f"x{RW_CFG['clients_per_host']}",
            1e6 / max(shared.virtual_throughput, 1e-9),
            f"shared={shared.virtual_throughput:.0f}/s "
            f"optimistic={opt.virtual_throughput:.0f}/s "
            f"exclusive_only={excl.virtual_throughput:.0f}/s "
            f"speedup={speedup:.2f}x opt_speedup={opt_speedup:.2f}x "
            f"rcas/rsharedacq={rcas_per_join:.2f} localRDMA=0",
        )
        if read_frac == 0.95 and opt_speedup <= RW_OPT_GATE:
            raise AssertionError(
                f"rw sweep: optimistic 95:5 speedup {opt_speedup:.2f}x did "
                f"not clear the shared-mode ceiling ({RW_OPT_GATE}x) — the "
                f"seqlock read path has regressed below the lease path")
    return sweep


def run_pipeline_sweep(report, sim_seed=0, smoke=False):
    """Doorbell coalescing: flush_ops=1 control vs the batched pipeline."""
    ops = PIPE_SMOKE_OPS if smoke else PIPE_OPS
    out = {"config": dict(num_hosts=SIM_HOSTS, clients_per_host=SIM_CPH,
                          num_shards=SIM_SHARDS, total_ops=ops)}
    runs = {}
    for label, flush in (("unbatched", 1), ("coalesced", 8)):
        r = run_lock_table_sim(
            "pipelined_read", num_hosts=SIM_HOSTS, clients_per_host=SIM_CPH,
            num_shards=SIM_SHARDS, total_ops=ops, seed=sim_seed,
            pipeline_flush_ops=flush)
        runs[label] = r
        out[label] = {
            "flush_ops": flush,
            "virtual_throughput": r.virtual_throughput,
            "ops": r.ops,
            "opt_reads": r.opt_reads,
            "opt_read_retries": r.opt_read_retries,
            "opt_read_fallbacks": r.opt_read_fallbacks,
            "pipeline_flushes": r.pipeline_flushes,
            "pipeline_flushed_ops": r.pipeline_flushed_ops,
            "doorbells_per_op": r.doorbells_per_op,
            "local_rdma": sum(
                v for k, v in r.cost["local"].items()
                if k.startswith("remote_") and k != "remote_doorbell"),
        }
        report(
            f"lock_table/sim/pipeline-{label}/hosts{SIM_HOSTS}x{SIM_CPH}",
            1e6 / max(r.virtual_throughput, 1e-9),
            f"vthru={r.virtual_throughput:.0f}/s "
            f"doorbells/op={r.doorbells_per_op:.3f} "
            f"flushes={r.pipeline_flushes} "
            f"opt_reads={r.opt_reads} wall={r.wall_seconds:.1f}s",
        )
    ctrl, coal = runs["unbatched"], runs["coalesced"]
    out["doorbell_reduction"] = round(
        ctrl.doorbells_per_op / max(coal.doorbells_per_op, 1e-9), 3)
    if coal.doorbells_per_op >= PIPE_DPO_GATE:
        raise AssertionError(
            f"pipeline sweep: coalesced doorbells-per-op "
            f"{coal.doorbells_per_op:.3f} is not under the "
            f"{PIPE_DPO_GATE} gate")
    if coal.doorbells_per_op >= ctrl.doorbells_per_op:
        raise AssertionError(
            f"pipeline sweep: coalescing paid {coal.doorbells_per_op:.3f} "
            f"doorbells/op vs {ctrl.doorbells_per_op:.3f} unbatched — the "
            f"pipeline is pure overhead here")
    return out


def run_recovery_sweep(report, sim_seed=0, smoke=False):
    """Crash-recovery before/after: ledger reclaim vs the amnesiac wedge."""
    ops = REC_SMOKE_OPS if smoke else REC_OPS
    ttl = REC_CFG["failover_ttl"]
    out = {"config": dict(REC_CFG, total_ops=ops)}
    runs = {}
    for label, reclaim in (("reclaim", True), ("amnesiac", False)):
        r = run_lock_table_sim("crash_restart", total_ops=ops, seed=sim_seed,
                               reclaim=reclaim, **REC_CFG)
        runs[label] = r
        out[label] = {
            "virtual_throughput": r.virtual_throughput,
            "ops": r.ops,
            "crashes": r.crashes,
            "kills": r.kills,
            "recovered_leases": r.reclaims,
            "recovery_p50_us": round(r.recovery_p50 * 1e6, 3),
            "recovery_p99_us": round(r.recovery_p99 * 1e6, 3),
            "recovery_max_us": round(r.recovery_max * 1e6, 3),
            "reclaim_fast": r.reclaim_fast,
            "reclaim_slow": r.reclaim_slow,
            "reclaim_shared": r.reclaim_shared,
            "reclaim_rejects": r.reclaim_rejects,
            "orphan_probes": r.orphan_probes,
            "orphan_adopts": r.orphan_adopts,
            "recovery_events": r.recovery_events,
        }
        report(
            f"lock_table/sim/recovery-{label}/hosts{REC_CFG['num_hosts']}"
            f"x{REC_CFG['clients_per_host']}",
            1e6 / max(r.virtual_throughput, 1e-9),
            f"vthru={r.virtual_throughput:.0f}/s crashes={r.crashes} "
            f"recovered={r.reclaims} "
            f"p99={r.recovery_p99 * 1e6:.0f}us "
            f"max={r.recovery_max * 1e6:.0f}us "
            f"fast={r.reclaim_fast} slow={r.reclaim_slow} "
            f"shared={r.reclaim_shared} orphan={r.orphan_adopts} "
            f"ttl={ttl * 1e6:.0f}us",
        )
    rec, amn = runs["reclaim"], runs["amnesiac"]
    if not rec.reclaims:
        raise AssertionError(
            "recovery sweep: no lease was ever reclaimed — the crash "
            "schedule missed every in-flight lease (config bug)")
    if rec.recovery_p99 > 0.1 * ttl:
        raise AssertionError(
            f"recovery sweep: p99 reclaim latency "
            f"{rec.recovery_p99 * 1e6:.0f}us exceeds 0.1x ttl "
            f"({0.1 * ttl * 1e6:.0f}us)")
    if amn.reclaims and amn.recovery_p99 <= rec.recovery_p99:
        raise AssertionError(
            "recovery sweep: the amnesiac wedge came back FASTER than "
            "ledger reclaim — the baseline is not measuring a wedge")
    out["wedge_over_reclaim_p99"] = round(
        amn.recovery_p99 / max(rec.recovery_p99, 1e-12), 2)
    return out


def run_failover_sweep(report, sim_seed=0, smoke=False):
    """Self-healing failover at 128 hosts: detection + takeover latency."""
    ops = FO_SMOKE_OPS if smoke else FO_OPS
    gate = FO_GATE_TTLS * FO_MEMBER_TTL
    out = {"config": dict(FO_CFG, total_ops=ops,
                          member_ttl_us=round(FO_MEMBER_TTL * 1e6, 3),
                          gate_ttls=FO_GATE_TTLS)}
    legs = {}
    for leg in ("home_death", "partition"):
        r = run_lock_table_sim(leg, total_ops=ops, seed=sim_seed, **FO_CFG)
        legs[leg] = r
        out[leg] = {
            "virtual_throughput": r.virtual_throughput,
            "ops": r.ops,
            "takeovers": r.takeovers,
            "takeover_refusals": r.takeover_refusals,
            "takeover_aborts": r.takeover_aborts,
            "epoch_aborts": r.epoch_aborts,
            "rehomed_keys": r.rehomed_keys,
            "guard_blocks": r.guard_blocks,
            "quorum_losses": r.quorum_losses,
            "minority_grants": r.minority_grants,
            "remote_timeouts": r.remote_timeouts,
            "token_regressions": r.token_regressions,
            "zombie_renews": r.zombie_renews,
            "detect_p99_us": round(r.detect_p99 * 1e6, 3),
            "failover_p50_us": round(r.failover_p50 * 1e6, 3),
            "failover_p99_us": round(r.failover_p99 * 1e6, 3),
            "failover_max_us": round(r.failover_max * 1e6, 3),
            "failover_events": r.failover_events,
            "fabric": r.fabric,
        }
        report(
            f"lock_table/sim/failover-{leg}/hosts{FO_CFG['num_hosts']}"
            f"x{FO_CFG['clients_per_host']}",
            1e6 / max(r.virtual_throughput, 1e-9),
            f"vthru={r.virtual_throughput:.0f}/s "
            f"takeovers={r.takeovers} refusals={r.takeover_refusals} "
            f"rehomed={r.rehomed_keys} "
            f"detect_p99={r.detect_p99 * 1e6:.0f}us "
            f"takeover_p99={r.failover_p99 * 1e6:.0f}us "
            f"gate={gate * 1e6:.0f}us minority_grants={r.minority_grants} "
            f"wall={r.wall_seconds:.1f}s",
        )
    hd, pt = legs["home_death"], legs["partition"]
    if not hd.takeovers or not hd.rehomed_keys:
        raise AssertionError(
            "failover sweep: home_death produced no committed takeover — "
            "the crash schedule or the suspicion thresholds are broken")
    if hd.detect_p99 > gate:
        raise AssertionError(
            f"failover sweep: detection p99 {hd.detect_p99 * 1e6:.0f}us "
            f"exceeds {FO_GATE_TTLS}x membership ttl "
            f"({gate * 1e6:.0f}us)")
    if hd.failover_p99 > gate:
        raise AssertionError(
            f"failover sweep: takeover p99 {hd.failover_p99 * 1e6:.0f}us "
            f"exceeds {FO_GATE_TTLS}x membership ttl "
            f"({gate * 1e6:.0f}us)")
    # The workload raises on any fencing regression / zombie renewal /
    # minority grant internally; these re-checks keep the gate visible in
    # the bench even if the workload's asserts are ever loosened.
    for name, r in legs.items():
        if r.token_regressions or r.zombie_renews:
            raise AssertionError(
                f"failover sweep: {name} saw "
                f"{r.token_regressions} token regressions / "
                f"{r.zombie_renews} zombie renewals past a takeover")
    if pt.minority_grants:
        raise AssertionError(
            f"failover sweep: {pt.minority_grants} grants landed on the "
            f"minority island inside the cut window")
    if not pt.takeover_refusals:
        raise AssertionError(
            "failover sweep: the partition never forced a quorum-guard "
            "refusal — the island is not attempting takeovers")
    return out


def _storm_leg(r):
    """The per-leg overload record shared by the sweep and its report."""
    return {
        "offered_load": r.offered_load,
        "offered": r.storm_offered,
        "goodput": r.storm_goodput,
        "goodput_shared": r.storm_goodput_shared,
        "shed": r.storm_shed,
        "table_sheds": r.sheds,
        "deadline_misses": r.storm_deadline_misses,
        "deadline_exceeded": r.deadline_exceeded,
        "late_grants": r.storm_late_grants,
        "acquire_p50_us": round(r.storm_acquire_p50 * 1e6, 3),
        "acquire_p99_us": round(r.storm_acquire_p99 * 1e6, 3),
        "hedges": r.hedges,
        "breaker_trips": r.breaker_trips,
        "breaker_refusals": r.breaker_refusals,
        "budget_refusals": r.budget_refusals,
        "op_timeouts": r.op_timeouts,
        "fabric_retries": r.fabric_retries,
        "congested": r.fabric.get("congested", 0),
        "token_regressions": r.token_regressions,
        "zombie_renews": r.zombie_renews,
    }


def run_overload_sweep(report, sim_seed=0, smoke=False):
    """Offered-load sweep 1x->10x: graceful shedding vs the retry storm."""
    ops = OV_SMOKE_OPS if smoke else OV_OPS
    loads = OV_SMOKE_LOADS if smoke else OV_LOADS
    out = {"config": dict(OV_CFG, total_ops=ops, loads=list(loads),
                          budget_us=round(OV_BUDGET * 1e6, 3))}
    on = {}
    for load in loads:
        r = run_lock_table_sim(
            "overload_storm", total_ops=ops, seed=sim_seed,
            offered_load=load, shedding=True, overload=OverloadPolicy(),
            **OV_CFG)
        on[load] = r
        out[f"on_{load:g}x"] = _storm_leg(r)
        report(
            f"lock_table/sim/overload-on{load:g}x/hosts{SIM_HOSTS}x{SIM_CPH}",
            1e6 / max(r.virtual_throughput, 1e-9),
            f"offered={r.storm_offered} goodput={r.storm_goodput} "
            f"shed={r.storm_shed} dl_miss={r.storm_deadline_misses} "
            f"p99={r.storm_acquire_p99 * 1e6:.0f}us "
            f"congested={r.fabric.get('congested', 0)} "
            f"wall={r.wall_seconds:.1f}s",
        )
    peak = loads[-1]
    off = run_lock_table_sim(
        "overload_storm", total_ops=ops, seed=sim_seed,
        offered_load=peak, shedding=False, overload=None, **OV_CFG)
    out[f"off_{peak:g}x"] = _storm_leg(off)
    report(
        f"lock_table/sim/overload-off{peak:g}x/hosts{SIM_HOSTS}x{SIM_CPH}",
        1e6 / max(off.virtual_throughput, 1e-9),
        f"offered={off.storm_offered} goodput={off.storm_goodput} "
        f"dl_miss={off.storm_deadline_misses} "
        f"p99={off.storm_acquire_p99 * 1e6:.0f}us "
        f"late={off.storm_late_grants} wall={off.wall_seconds:.1f}s",
    )
    base, top = on[loads[0]], on[peak]
    out["goodput_retention"] = round(
        top.storm_goodput / max(base.storm_goodput, 1), 4)
    out["off_over_on_goodput"] = round(
        off.storm_goodput / max(top.storm_goodput, 1), 4)
    if base.storm_goodput < OV_BASE_SERVE * base.storm_offered:
        raise AssertionError(
            f"overload sweep: the 1x baseline served only "
            f"{base.storm_goodput}/{base.storm_offered} arrivals "
            f"(floor {OV_BASE_SERVE:.0%}) — the sweep is measuring an "
            f"already-overloaded baseline")
    if top.storm_goodput < OV_RETENTION * base.storm_goodput:
        raise AssertionError(
            f"overload sweep: goodput at {peak:g}x fell to "
            f"{top.storm_goodput} vs {base.storm_goodput} at 1x "
            f"(floor {OV_RETENTION:.0%}) — shedding is not protecting "
            f"feasible work")
    if off.storm_goodput >= top.storm_goodput:
        raise AssertionError(
            f"overload sweep: the shedding-OFF control served "
            f"{off.storm_goodput} >= {top.storm_goodput} with the stack ON "
            f"— the overload machinery is pure overhead here")
    for load, r in on.items():
        if r.storm_acquire_p99 > OV_P99_BUDGETS * OV_BUDGET:
            raise AssertionError(
                f"overload sweep: non-shed acquire p99 at {load:g}x is "
                f"{r.storm_acquire_p99 * 1e6:.0f}us, past "
                f"{OV_P99_BUDGETS}x the {OV_BUDGET * 1e6:.0f}us budget — "
                f"deadline overshoot is not bounded")
        if r.token_regressions or r.zombie_renews:
            raise AssertionError(
                f"overload sweep: {load:g}x saw {r.token_regressions} "
                f"token regressions / {r.zombie_renews} zombie renewals "
                f"under shedding")
    return out


def run_sim(report, sim_seed=0, smoke=False, zipf_run=None):
    """The deterministic virtual-time sweep; returns (rows, wall_seconds).

    ``rows`` contains only seed-determined fields (exact counters, virtual
    throughput, event counts) — two runs with the same seed must compare
    equal, which the CI determinism gate enforces.  Wall-clock durations
    live in the separate ``wall_seconds`` dict.  ``zipf_run`` lets the
    caller hand in the inflation sweep's ON leg (identical configuration)
    so the densest simulation is not paid twice.
    """
    ops_table = SIM_SMOKE_OPS if smoke else SIM_OPS
    rows, wall = {}, {}
    for workload in SIM_WORKLOADS:
        kwargs = {}
        r = None
        if workload == "zipfian":
            # The acceptance configuration: sticky hot-key clients over an
            # inflating table.  (Without inflation this config's CAS storm
            # is the OFF leg of run_inflation_sweep, not a standing row.)
            if zipf_run is not None and ops_table[workload] == INFL_OPS:
                r = zipf_run  # identical config: reuse the sweep's ON leg
            else:
                kwargs = dict(inflation=InflationPolicy(),
                              max_events=ZIPF_MAX_EVENTS)
        if workload == "crash_restart":
            # The 300 us failover TTL leaves nothing alive to reclaim by
            # the time a restart lands; run this row at the recovery
            # sweep's lease scale so its counters exercise the full path.
            kwargs = dict(failover_ttl=REC_TTL, crash_warmup=2e-3,
                          crash_spacing=REC_TTL / 8,
                          restart_delay=REC_TTL / 8)
        if workload in ("home_death", "partition"):
            # Same lease scale as the recovery sweep: 1 ms leases keep
            # client traffic (and the heartbeat region) in flight at the
            # crash/cut instants.  The membership TTL derives from host
            # count inside the workload.
            kwargs = dict(failover_ttl=REC_TTL)
        if workload == "overload_storm":
            # The standing row is the 1x point with the full overload
            # stack ON; run_overload_sweep owns the loaded legs.
            kwargs = dict(overload=OverloadPolicy(),
                          deadline_budget=OV_BUDGET)
        if r is None:
            r = run_lock_table_sim(
                workload, num_hosts=SIM_HOSTS, clients_per_host=SIM_CPH,
                num_shards=SIM_SHARDS, total_ops=ops_table[workload],
                seed=sim_seed, **kwargs,
            )
        cfg = f"{workload}/hosts{SIM_HOSTS}x{SIM_CPH}/shards{SIM_SHARDS}"
        rows[cfg] = r.row()
        wall[cfg] = round(r.wall_seconds, 3)
        rdma = sum(v for k, v in r.cost["remote"].items()
                   if k.startswith("remote_") and k != "remote_doorbell")
        extra = ""
        if r.grants_shared:
            extra = (f"gS={r.grants_shared} gX={r.grants_exclusive} "
                     f"intent={r.intent_blocks} ")
        if workload == "reader_flood":
            extra += (f"writer_grants={r.writer_grants} "
                      f"writer_max_wait={r.writer_max_wait * 1e6:.0f}us ")
        if workload == "crash_restart":
            extra += (f"crashes={r.crashes} recovered={r.reclaims} "
                      f"recovery_p99={r.recovery_p99 * 1e6:.0f}us ")
        if workload == "overload_storm":
            extra += (f"offered={r.storm_offered} "
                      f"goodput={r.storm_goodput} shed={r.storm_shed} "
                      f"storm_p99={r.storm_acquire_p99 * 1e6:.0f}us ")
        if workload == "pipelined_read":
            extra += (f"opt_reads={r.opt_reads} "
                      f"flushes={r.pipeline_flushes} "
                      f"fallbacks={r.opt_read_fallbacks} ")
        report(
            f"lock_table/sim/{cfg}",
            1e6 / max(r.virtual_throughput, 1e-9),  # virtual µs per op
            f"vthru={r.virtual_throughput:.0f}/s jain={r.jain:.3f} "
            f"ops={r.ops} rejects={r.rejects} exp={r.expirations} "
            f"rRDMA/op={rdma / max(r.ops, 1):.2f} "
            f"doorbells/op={r.cost['remote']['remote_doorbell'] / max(r.ops, 1):.2f} "
            f"{extra}"
            f"wall={r.wall_seconds:.1f}s localRDMA=0",
        )
    return rows, wall


def json_extra():
    """Hook for ``benchmarks.run --json``: the before/after trajectory."""
    return json_payload(_LAST["results"], _LAST["seconds"], _LAST["sim"])


def run(report, seconds=0.7, seeds=SEEDS, mode="both", sim_seed=0,
        smoke=False):
    _LAST["results"] = results = []
    _LAST["seconds"] = seconds
    _LAST["sim"] = None
    if mode in ("threaded", "both"):
        num_hosts = 4
        for workload in ("home", "uniform", "read_heavy", "renew",
                         "renew_remote", "batch"):
            base = None
            for shards in (1, 4, 16):
                r = _bench_median(num_hosts, shards, workload, seconds, seeds)
                if shards == 1:
                    base = r["throughput"]
                r["speedup_vs_1shard"] = r["throughput"] / max(base, 1e-9)
                if r["throughput_cv"] > CV_WARN:
                    print(f"# WARNING: lock_table/{workload}/shards{shards} "
                          f"throughput cv={r['throughput_cv']:.3f} > "
                          f"{CV_WARN} — the median is noise-dominated; "
                          f"rerun on a quieter box before recording it",
                          file=sys.stderr)
                results.append(r)
                report(
                    f"lock_table/{workload}/hosts{num_hosts}/shards{shards}",
                    1e6 / max(r["throughput"], 1e-9),  # µs per operation
                    f"thru={r['throughput']:.0f}/s x{r['speedup_vs_1shard']:.2f} "
                    f"jain={r['jain']:.3f} "
                    f"cv={r['throughput_cv']:.3f} "
                    f"rRDMA/op={r['remote_rdma_per_op']:.2f} "
                    f"doorbells/op={r['remote_doorbells_per_op']:.2f} "
                    f"fastrenew={r['fast_renews']} localRDMA=0",
                )
    if mode in ("sim", "both"):
        inflation, zipf_on = run_inflation_sweep(report, sim_seed=sim_seed,
                                                 smoke=smoke)
        rows, wall = run_sim(report, sim_seed=sim_seed, smoke=smoke,
                             zipf_run=zipf_on)
        sweep = run_rw_sweep(report, sim_seed=sim_seed, smoke=smoke)
        pipeline = run_pipeline_sweep(report, sim_seed=sim_seed, smoke=smoke)
        recovery = run_recovery_sweep(report, sim_seed=sim_seed, smoke=smoke)
        failover = run_failover_sweep(report, sim_seed=sim_seed, smoke=smoke)
        overload = run_overload_sweep(report, sim_seed=sim_seed, smoke=smoke)
        _LAST["sim"] = {
            "seed": sim_seed,
            "config": {"hosts": SIM_HOSTS, "clients_per_host": SIM_CPH,
                       "shards": SIM_SHARDS},
            "rows": rows,
            "wall_seconds": wall,
            "read_write_sweep": {
                "config": dict(RW_CFG, total_ops=RW_OPS),
                "ratios": sweep,
            },
            "pipeline": pipeline,
            "recovery": recovery,
            "failover": failover,
            "inflation": inflation,
            "overload": overload,
        }


def json_payload(results, seconds, sim=None):
    """The machine-readable perf-trajectory record (BENCH_lock_table.json)."""
    current = {}
    for r in results:
        current[f"{r['workload']}/shards{r['shards']}"] = {
            k: v for k, v in r.items() if k not in ("workload", "shards")
        }
    speedups = {
        cfg: round(current[cfg]["throughput"] / before, 3)
        for cfg, before in BASELINE.items()
        if cfg in current and before > 0
    }
    payload = {
        "bench": "lock_table",
        "config": {
            "hosts": 4,
            "clients_per_host": 2,
            "seconds": seconds,
            "keys_per_host": KEYS_PER_HOST,
            "batch_keys": BATCH_KEYS,
            "remote_delay_us": REMOTE_DELAY * 1e6,
            "cpu_count": os.cpu_count(),
        },
        "baseline_pre_pr": BASELINE,
        # BASELINE was recorded on a BASELINE_CPU_COUNT-core box; threaded
        # speedup-vs-baseline ratios from any other shape measure the box,
        # not the protocol.
        "baseline_comparable": os.cpu_count() == BASELINE_CPU_COUNT,
        "current": current,
        "speedup_vs_baseline": speedups,
    }
    if sim is not None:
        payload["sim"] = sim
    return payload


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="CI config: short threaded runs, smaller sim op "
                         "targets (the 64x16 zipfian sweep stays full-size)")
    ap.add_argument("--mode", choices=("threaded", "sim", "both"),
                    default="both",
                    help="threaded = wall-clock thread-per-client; sim = "
                         "deterministic virtual-time engine; both (default)")
    ap.add_argument("--sim-seed", type=int, default=0,
                    help="seed for the sim sweep (same seed => byte-"
                         "identical counters; CI diffs two runs)")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write the before/after results to PATH")
    args = ap.parse_args()
    seconds = 0.1 if args.smoke else 0.7
    seeds = (0,) if args.smoke else SEEDS

    rows = []

    def report(name, us, derived=""):
        rows.append(name)
        print(f"{name},{us:.3f},{derived}")

    run(report, seconds=seconds, seeds=seeds, mode=args.mode,
        sim_seed=args.sim_seed, smoke=args.smoke)
    print(f"# {len(rows)} lock-table rows")
    if args.json:
        payload = json_extra()
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"# wrote {args.json}")
        for cfg, x in sorted(payload["speedup_vs_baseline"].items()):
            print(f"#   {cfg}: {x:.2f}x vs pre-PR")


if __name__ == "__main__":
    main()
