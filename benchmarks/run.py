"""Benchmark harness — one module per paper table/claim.

Prints ``name,us_per_call,derived`` CSV.  Modules:

  lock_ops      — RDMA-op cost claims (paper §3.1)         [the paper's table]
  lock_compare  — throughput/fairness vs naive/RPC/filter  (paper §1, §3, §4)
  lock_table_bench — sharded table: throughput scaling + fairness vs 1 shard
  collectives   — cohort vs flat DCN traffic               (TPU adaptation)
  step_bench    — end-to-end step times (CPU, smoke configs)
  kernel_bench  — Pallas kernels: tiles + correctness
"""

import sys
import traceback


def main() -> None:
    rows = []

    def report(name, us_per_call, derived=""):
        rows.append((name, us_per_call, derived))
        print(f"{name},{us_per_call:.3f},{derived}")

    from . import (collectives, kernel_bench, lock_compare, lock_ops,
                   lock_table_bench, step_bench)

    failures = []
    for mod in (lock_ops, lock_compare, lock_table_bench, collectives,
                step_bench, kernel_bench):
        try:
            mod.run(report)
        except Exception:
            traceback.print_exc()
            failures.append(mod.__name__)
    if failures:
        print(f"BENCHMARK FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)
    print(f"# {len(rows)} benchmark rows")


if __name__ == "__main__":
    main()
