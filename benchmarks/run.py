"""Benchmark harness — one module per paper table/claim.

Prints ``name,us_per_call,derived`` CSV.  Modules:

  lock_ops      — RDMA-op cost claims (paper §3.1)         [the paper's table]
  lock_compare  — throughput/fairness vs naive/RPC/filter  (paper §1, §3, §4)
  lock_table_bench — sharded table: scaling, fairness, hot-path fast paths
  collectives   — cohort vs flat DCN traffic               (TPU adaptation)
  step_bench    — end-to-end step times (CPU, smoke configs)
  kernel_bench  — Pallas kernels: tiles + correctness

``--json OUT`` additionally writes each module's results to
``OUT/BENCH_<name>.json`` (default OUT: the repo root), the machine-readable
perf trajectory.  A module may expose ``BENCH_NAME`` (file-name stem) and
``json_extra()`` (rich payload merged into its record, e.g. the lock table's
before/after comparison).
"""

import argparse
import json
import pathlib
import sys
import traceback

REPO_ROOT = pathlib.Path(__file__).resolve().parent.parent


def main() -> None:
    ap = argparse.ArgumentParser(description="run all benchmarks")
    ap.add_argument(
        "--json", metavar="OUT", nargs="?", const=str(REPO_ROOT), default=None,
        help="write BENCH_<name>.json per module into OUT (default: repo root)",
    )
    args = ap.parse_args()
    rows = []

    from . import (collectives, kernel_bench, lock_compare, lock_ops,
                   lock_table_bench, step_bench)

    failures = []
    for mod in (lock_ops, lock_compare, lock_table_bench, collectives,
                step_bench, kernel_bench):
        mod_rows = []

        def report(name, us_per_call, derived="", _rows=mod_rows):
            rows.append((name, us_per_call, derived))
            _rows.append({"name": name, "us_per_call": us_per_call,
                          "derived": derived})
            print(f"{name},{us_per_call:.3f},{derived}")

        try:
            mod.run(report)
        except Exception:
            traceback.print_exc()
            failures.append(mod.__name__)
            continue
        if args.json:
            name = getattr(mod, "BENCH_NAME", mod.__name__.rsplit(".", 1)[-1])
            payload = {"bench": name, "rows": mod_rows}
            extra = getattr(mod, "json_extra", None)
            if extra is not None:
                payload.update(extra())
            out = pathlib.Path(args.json) / f"BENCH_{name}.json"
            out.parent.mkdir(parents=True, exist_ok=True)
            out.write_text(json.dumps(payload, indent=2, sort_keys=True) + "\n")
            print(f"# wrote {out}")
    if failures:
        print(f"BENCHMARK FAILURES: {failures}", file=sys.stderr)
        sys.exit(1)
    print(f"# {len(rows)} benchmark rows")


if __name__ == "__main__":
    main()
