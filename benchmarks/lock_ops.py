"""Benchmark 1 — RDMA-op accounting per lock acquisition (paper §3.1 claims).

The paper has no perf tables (it's a technical report); its quantitative
content is the *operation-cost* claims.  This benchmark measures them on the
simulated fabric and reports ops/acquisition for each lock and process class:

  claim 1: ALock local processes issue 0 RDMA ops;
  claim 2: lone remote acquire = 1 rCAS (queue) + Peterson engagement;
  claim 3: queued remote acquire adds 1 rWrite, then spins locally;
  claim 4: release ≤ 1 rCAS + 1 rWrite;
  contrast: the naive loopback lock charges RDMA ops to *everyone* and spins
  remotely (unbounded rCAS under contention).
"""

import random
import threading

from repro.core import ALock, AsymmetricMemory, NaiveRCASLock, make_scheduler


def _measure(lock_cls, nodes, iters=200, seed=0, budget=4):
    mem = AsymmetricMemory(3, sched=make_scheduler(random.Random(seed), 0.1))
    if lock_cls is ALock:
        lock = ALock(mem, home_node=0, init_budget=budget)
    else:
        lock = lock_cls(mem, home_node=0)
    procs = {}
    lk = threading.Lock()

    def worker(node):
        p = mem.spawn(node)
        with lk:
            procs[p.pid] = p
        for _ in range(iters):
            lock.lock(p)
            lock.unlock(p)

    ts = [threading.Thread(target=worker, args=(n,)) for n in nodes]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    local = [p for p in procs.values() if p.node == 0]
    remote = [p for p in procs.values() if p.node != 0]
    out = {}
    for name, group in (("local", local), ("remote", remote)):
        if not group:
            continue
        acq = iters * len(group)
        rdma = sum(p.counts.rdma_ops for p in group)
        loc = sum(p.counts.local_ops for p in group)
        out[name] = (rdma / acq, loc / acq)
    return out


def run(report):
    nodes = [0, 0, 1, 1, 2]
    a = _measure(ALock, nodes)
    n = _measure(NaiveRCASLock, nodes)
    report("lock_ops/alock_local_rdma_per_acq", a["local"][0],
           "claim1: ==0")
    report("lock_ops/alock_remote_rdma_per_acq", a["remote"][0],
           "claims 2-4: small constant (queue rCAS + link + release + "
           "Peterson engagement)")
    report("lock_ops/naive_local_rdma_per_acq", n["local"][0],
           "loopback overhead the paper eliminates")
    report("lock_ops/naive_remote_rdma_per_acq", n["remote"][0],
           "remote spinning: unbounded under contention")
    lone = _measure(ALock, [1], iters=100)
    report("lock_ops/alock_lone_remote_rdma_per_acq", lone["remote"][0],
           "lone remote: 1 rCAS acquire + 1 rCAS release + victim write "
           "+ peterson read")
    assert a["local"][0] == 0.0, "claim 1 violated"
