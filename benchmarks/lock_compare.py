"""Benchmark 2 — throughput & fairness: ALock vs naive-rCAS vs RPC vs filter.

Remote operations carry an injected latency (RDMA is ~10× local access,
paper §1), so the comparison reflects the asymmetry the design targets.
Reported: critical sections/second and a Jain fairness index over per-thread
acquisition counts.
"""

import random
import threading
import time

from repro.core import (
    ALock,
    AsymmetricMemory,
    FilterLock,
    NaiveRCASLock,
    RPCLock,
    make_scheduler,
)

REMOTE_DELAY = 20e-6  # 20 µs per remote op


def _latency_sched(rng):
    base = make_scheduler(rng, 0.05)
    return base


class _DelayMem(AsymmetricMemory):
    def rread(self, p, reg):
        time.sleep(REMOTE_DELAY)
        return super().rread(p, reg)

    def rwrite(self, p, reg, value):
        time.sleep(REMOTE_DELAY)
        super().rwrite(p, reg, value)

    def rcas(self, p, reg, expected, swap):
        time.sleep(REMOTE_DELAY)
        return super().rcas(p, reg, expected, swap)


def _bench(kind, nodes, seconds=1.0, seed=0):
    rng = random.Random(seed)
    mem = _DelayMem(3, sched=_latency_sched(rng))
    procs = [mem.spawn(n) for n in nodes]
    if kind == "alock":
        lock = ALock(mem, 0, init_budget=4)
    elif kind == "naive":
        lock = NaiveRCASLock(mem, 0)
    elif kind == "rpc":
        lock = RPCLock(mem, 0)
    elif kind == "filter":
        lock = FilterLock(mem, 0, [p.pid for p in procs])
    counts = [0] * len(procs)
    stop = threading.Event()

    def worker(i):
        p = procs[i]
        while not stop.is_set():
            lock.lock(p)
            counts[i] += 1
            lock.unlock(p)

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(len(procs))]
    t0 = time.time()
    for t in ts:
        t.start()
    time.sleep(seconds)
    stop.set()
    for t in ts:
        t.join()
    dt = time.time() - t0
    if kind == "rpc":
        lock.shutdown()
    total = sum(counts)
    jain = (total ** 2) / (len(counts) * sum(c * c for c in counts)) if total else 0
    return total / dt, jain


def run(report):
    nodes = [0, 0, 0, 1, 1, 2]  # 3 local, 3 remote
    for kind in ("alock", "naive", "rpc", "filter"):
        thr, jain = _bench(kind, nodes, seconds=0.8)
        report(f"lock_compare/{kind}_cs_per_sec", 1e6 / max(thr, 1e-9),
               f"throughput={thr:.0f}/s jain_fairness={jain:.3f}")
